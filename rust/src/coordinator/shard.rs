//! Sharded flow-affinity serving tier (DESIGN.md §12).
//!
//! N2Net's pitch is line-rate inference; one software engine cannot
//! emulate that, so this layer scales out the way a rack does: an
//! RSS-style dispatcher flow-hashes every frame (bounds-checked
//! [`crate::net::packet::parse_flow_key`] / [`flow_hash`] — same flow,
//! same shard, always) across N per-shard serving lanes. Each shard
//! owns its own [`InferenceBackend`], its own [`Batcher`], and a
//! bounded SPSC-style queue in front of it; the dispatcher is the
//! single producer, the shard worker the single consumer.
//!
//! Overload is explicit, never silent: [`OverflowPolicy::Block`]
//! applies backpressure to the producer (counted per shard as
//! `backpressure_waits`), [`OverflowPolicy::Drop`] sheds the frame at
//! the full queue (counted per shard as `dropped`; the packet's output
//! word stays 0, exactly what a switch that tail-drops would deliver).
//!
//! Hot-swaps ([`crate::deploy::Deployment::swap_model`]) are picked up
//! per shard at batch boundaries — one atomic version peek, same
//! protocol as [`super::Engine`] — so during a swap different shards
//! may briefly serve different versions. [`ShardedReport`] surfaces
//! that skew (`version_min..version_max`) instead of hiding it.
//!
//! Because every shard worker pulls from a queue that can stall
//! mid-stream, the worker loop bounds its wait by
//! [`Batcher::time_until_deadline`] and flushes via `poll_deadline` on
//! timeout — without that, a sub-`max_size` tail would sit stranded
//! until the stream closed (the stranded-tail bug; regression test
//! below).
//!
//! **Live reconfiguration** (DESIGN.md §14): the control plane can
//! reshape a serving tier while streams are open. The reconfigurable
//! knobs live in one shared [`TierCell`] of atomics:
//!
//! * [`ShardedEngine::set_overflow`] — the dispatcher re-reads the
//!   policy with one atomic load per push, so a Block↔Drop flip takes
//!   effect on the very next frame (frames already queued are
//!   unaffected; overflow policy only ever governs the push side);
//! * [`ShardedEngine::set_backend`] — each shard worker peeks the kind
//!   once per batch (alongside the version peek it already does) and
//!   rebuilds its backend from the currently *published* artifact —
//!   the same [`crate::deploy::SwapCell`] path hot-swaps use — so a
//!   switch lands at a batch boundary, never mid-batch;
//! * [`ShardedEngine::reshard`] — changes the shard count via
//!   **drain-and-rebuild**: the generation counter bumps, and a
//!   [`LiveStream`] dispatcher observing it finishes the old stream
//!   (every queued frame classified, workers joined) before opening
//!   the new one. The global drain barrier is what makes the flow
//!   guarantee trivial: a flow's frames are served entirely by the old
//!   tier or entirely by the new one from the barrier on — old-or-new
//!   per flow, never interleaved — and outputs stay in global ingest
//!   order because each epoch's report is itself ingest-ordered.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{BackendKind, InferenceBackend};
use crate::baseline::LutClassifier;
use crate::bnn::BnnModel;
use crate::compiler::CompiledModel;
use crate::deploy::ModelSlot;
use crate::error::{Error, Result};
use crate::net::packet::flow_hash;
use crate::obs::{EventKind, MetricsRegistry, Tracer};
use crate::telemetry::{ClassMix, Counter, EngineMetrics, CLASS_BUCKETS};

use super::batcher::{Batch, Batcher, BatchPolicy};
use super::engine::EngineSource;

/// How the dispatcher behaves when a shard's queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Backpressure: the producer waits for the shard to drain
    /// (lossless — the default, and what the bit-exactness properties
    /// assume).
    Block,
    /// Shed load: the frame is dropped at the full queue and its output
    /// word stays 0 (the tail-drop a real ingress would do).
    Drop,
}

impl OverflowPolicy {
    /// The CLI / policy-file spelling.
    pub fn name(self) -> &'static str {
        match self {
            OverflowPolicy::Block => "block",
            OverflowPolicy::Drop => "drop",
        }
    }

    /// Parse a CLI / policy-file spelling.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "block" => Ok(OverflowPolicy::Block),
            "drop" => Ok(OverflowPolicy::Drop),
            other => Err(Error::Config(format!(
                "unknown overflow policy {other:?} (expected block|drop)"
            ))),
        }
    }
}

/// Most shards a tier can be resharded to — the legal-range bound
/// policy validation enforces at controller construction.
pub const MAX_SHARDS: usize = 64;

// Atomic encodings for the TierCell (kept local: the cell is an
// implementation detail of the reconfiguration protocol).
fn overflow_to_u8(p: OverflowPolicy) -> u8 {
    match p {
        OverflowPolicy::Block => 0,
        OverflowPolicy::Drop => 1,
    }
}

fn overflow_from_u8(v: u8) -> OverflowPolicy {
    if v == 1 {
        OverflowPolicy::Drop
    } else {
        OverflowPolicy::Block
    }
}

fn backend_to_u8(k: BackendKind) -> u8 {
    match k {
        BackendKind::Scalar => 0,
        BackendKind::Batched => 1,
        BackendKind::Reference => 2,
        BackendKind::Lut => 3,
        BackendKind::Specialized => 4,
    }
}

fn backend_from_u8(v: u8) -> BackendKind {
    match v {
        0 => BackendKind::Scalar,
        2 => BackendKind::Reference,
        3 => BackendKind::Lut,
        4 => BackendKind::Specialized,
        _ => BackendKind::Batched,
    }
}

/// The runtime-reconfigurable tier knobs, shared between the engine
/// (the control plane's write side) and every live dispatcher / shard
/// worker (read side: one relaxed atomic load per push or per batch —
/// nothing new on the per-packet classify path).
#[derive(Debug)]
struct TierCell {
    overflow: AtomicU8,
    backend: AtomicU8,
    n_shards: AtomicUsize,
    /// Bumped by every reshard; a [`LiveStream`] dispatcher observing a
    /// change drains and rebuilds before accepting the next frame.
    generation: AtomicU64,
}

impl TierCell {
    fn new(config: &ShardConfig) -> Self {
        Self {
            overflow: AtomicU8::new(overflow_to_u8(config.overflow)),
            backend: AtomicU8::new(backend_to_u8(config.backend)),
            n_shards: AtomicUsize::new(config.n_shards.max(1)),
            generation: AtomicU64::new(0),
        }
    }
}

/// Sharded-serving configuration.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Number of serving shards (≥1).
    pub n_shards: usize,
    /// Per-shard queue bound, in frames.
    pub queue_capacity: usize,
    pub overflow: OverflowPolicy,
    /// Which [`InferenceBackend`] each shard drives.
    pub backend: BackendKind,
    /// Batch formation policy for each shard's pull loop.
    pub batch: BatchPolicy,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            n_shards: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(4),
            queue_capacity: 4096,
            overflow: OverflowPolicy::Block,
            backend: BackendKind::default(),
            batch: BatchPolicy::default(),
        }
    }
}

/// Per-shard serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    /// Frames delivered to (and classified by) this shard.
    pub packets: u64,
    /// Batches the shard's backend executed.
    pub batches: u64,
    pub parse_errors: u64,
    /// Frames shed at this shard's full queue ([`OverflowPolicy::Drop`]).
    pub dropped: u64,
    /// Times the dispatcher had to wait on this shard's full queue
    /// ([`OverflowPolicy::Block`]).
    pub backpressure_waits: u64,
    /// Publication version this shard last served.
    pub model_version: u64,
}

/// Merged result of a sharded run: aggregate stats plus the per-shard
/// breakdown (imbalance and hot-swap version skew stay visible).
#[derive(Clone, Debug)]
pub struct ShardedReport {
    /// Output word per input frame, in ingest order; 0 for malformed or
    /// dropped frames.
    pub outputs: Vec<u32>,
    pub n_packets: usize,
    /// Aggregate host wall-clock packets/second.
    pub sim_pps: f64,
    /// What one modeled ASIC would do (line rate / passes).
    pub modeled_pps: f64,
    pub parse_errors: u64,
    /// Total frames shed across all shards.
    pub dropped: u64,
    pub backend: &'static str,
    pub per_shard: Vec<ShardStats>,
    /// Lowest / highest publication version any shard last served —
    /// equal except transiently during a hot-swap.
    pub version_min: u64,
    pub version_max: u64,
}

/// max/mean over per-shard load counts: 1.0 = perfectly balanced,
/// higher under skew, and 0.0 — never NaN — for an idle or empty tier.
/// The single definition behind [`ShardedReport::imbalance`] and the
/// control plane's windowed
/// [`SignalWindow::imbalance`](crate::controlplane::SignalWindow::imbalance).
pub fn load_imbalance(loads: &[u64]) -> f64 {
    let mean = loads.iter().sum::<u64>() as f64 / loads.len().max(1) as f64;
    let max = loads.iter().max().copied().unwrap_or(0) as f64;
    if mean > 0.0 {
        max / mean
    } else {
        0.0
    }
}

impl ShardedReport {
    /// max/mean shard load (1.0 = perfectly balanced; a zipf heavy
    /// hitter pushes this up under flow-affinity dispatch).
    pub fn imbalance(&self) -> f64 {
        let loads: Vec<u64> = self.per_shard.iter().map(|s| s.packets).collect();
        load_imbalance(&loads)
    }

    /// Register this report's (plain, already-final) numbers into a
    /// registry under `tier.*` names — the machine-readable surface
    /// behind [`ShardedReport::expose`].
    pub fn register_into(&self, reg: &MetricsRegistry) {
        let v = self.n_packets as u64;
        reg.counter_fn("tier.packets", move || v);
        let v = self.parse_errors;
        reg.counter_fn("tier.parse_errors", move || v);
        let v = self.dropped;
        reg.counter_fn("tier.dropped", move || v);
        let v = self.per_shard.len() as u64;
        reg.gauge_fn("tier.n_shards", move || v);
        let v = self.version_min;
        reg.gauge_fn("tier.version_min", move || v);
        let v = self.version_max;
        reg.gauge_fn("tier.version_max", move || v);
        for st in &self.per_shard {
            let p = format!("tier.shard{}", st.shard);
            let v = st.packets;
            reg.counter_fn(&format!("{p}.packets"), move || v);
            let v = st.batches;
            reg.counter_fn(&format!("{p}.batches"), move || v);
            let v = st.parse_errors;
            reg.counter_fn(&format!("{p}.parse_errors"), move || v);
            let v = st.dropped;
            reg.counter_fn(&format!("{p}.dropped"), move || v);
            let v = st.backpressure_waits;
            reg.counter_fn(&format!("{p}.backpressure_waits"), move || v);
            let v = st.model_version;
            reg.gauge_fn(&format!("{p}.model_version"), move || v);
        }
    }

    /// Prometheus-style exposition of the report via the unified
    /// registry (the renderer the bespoke string builder was replaced
    /// by — ISSUE 9 satellite).
    pub fn expose(&self) -> String {
        let reg = MetricsRegistry::new();
        self.register_into(&reg);
        reg.expose()
    }

    /// Thin compat shim: the human header plus the compact per-shard
    /// table the CLI and shard tests assert (`shard 0: ...`). Machine
    /// consumers use [`ShardedReport::expose`] instead.
    pub fn render(&self) -> String {
        let mut s = format!(
            "sharded serve: {} packets over {} shards ({} backend) — \
             {:.2} M pkt/s aggregate (modeled ASIC {:.0} M/s per chip)\n\
             parse_errors={} dropped={} imbalance={:.2} versions=v{}..v{}\n",
            self.n_packets,
            self.per_shard.len(),
            self.backend,
            self.sim_pps / 1e6,
            self.modeled_pps / 1e6,
            self.parse_errors,
            self.dropped,
            self.imbalance(),
            self.version_min,
            self.version_max,
        );
        for st in &self.per_shard {
            s.push_str(&format!(
                "  shard {}: packets={} batches={} parse_errors={} dropped={} \
                 waits={} v{}\n",
                st.shard,
                st.packets,
                st.batches,
                st.parse_errors,
                st.dropped,
                st.backpressure_waits,
                st.model_version,
            ));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Cumulative tier telemetry (the control plane's pull-based signal source)
// ---------------------------------------------------------------------------

/// Cumulative, atomically readable serving counters for ONE shard,
/// shared between the shard worker (writer, once per batch) and any
/// observer thread (reader). Unlike [`ShardStats`] — which is a
/// per-trace result merged at `finish` — these survive across streams
/// on the same [`ShardedEngine`], which is what lets a controller
/// *pull* consistent-enough snapshots while serving continues: no
/// channel, no lock, and nothing added on the per-packet path
/// (DESIGN.md §13).
#[derive(Debug, Default)]
pub struct ShardTelemetry {
    /// Frames delivered to (and classified by) this shard.
    pub packets: Counter,
    /// Batches the shard's backend executed.
    pub batches: Counter,
    pub parse_errors: Counter,
    /// Frames shed at this shard's full queue ([`OverflowPolicy::Drop`]).
    pub dropped: Counter,
    /// Dispatcher waits on this shard's full queue
    /// ([`OverflowPolicy::Block`]).
    pub backpressure_waits: Counter,
    /// Publication version this shard last served.
    pub model_version: AtomicU64,
}

impl ShardTelemetry {
    /// Plain-number snapshot of the counters.
    pub fn counts(&self) -> ShardCounts {
        ShardCounts {
            packets: self.packets.get(),
            batches: self.batches.get(),
            parse_errors: self.parse_errors.get(),
            dropped: self.dropped.get(),
            backpressure_waits: self.backpressure_waits.get(),
            model_version: self.model_version.load(Ordering::Relaxed),
        }
    }
}

/// One shard's cumulative counters as plain numbers (a snapshot of
/// [`ShardTelemetry`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardCounts {
    pub packets: u64,
    pub batches: u64,
    pub parse_errors: u64,
    pub dropped: u64,
    pub backpressure_waits: u64,
    pub model_version: u64,
}

/// Cumulative snapshot of the whole sharded tier, taken by
/// [`ShardedEngine::snapshot`]. The control plane differences two
/// consecutive snapshots into one
/// [`SignalWindow`](crate::controlplane::SignalWindow); everything here
/// is counters the tier maintains anyway, so taking a snapshot costs a
/// few atomic loads and never touches the packet path.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TierSnapshot {
    pub per_shard: Vec<ShardCounts>,
    /// Cumulative output-class histogram (low-bits bucketing, see
    /// [`crate::telemetry::ClassMix`]).
    pub classes: [u64; CLASS_BUCKETS],
    /// Cumulative batch-latency log₂ buckets
    /// ([`crate::telemetry::Histogram::bucket_counts`]).
    pub latency_buckets: Vec<u64>,
}

// ---------------------------------------------------------------------------
// Bounded SPSC-style queue (std-only: Mutex + two Condvars)
// ---------------------------------------------------------------------------

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded queue between the dispatcher (single producer) and one shard
/// worker (single consumer). `pop_timeout` keeps returning buffered
/// items after `close`, reporting `Closed` only once drained — the
/// worker never loses the tail.
struct ShardQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

enum Pop<T> {
    Item(T),
    TimedOut,
    Closed,
}

impl<T> ShardQueue<T> {
    fn new(capacity: usize) -> Self {
        Self {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Blocking push (backpressure). Returns `(pushed, had_to_wait)`;
    /// `pushed` is false only when the queue was closed under us (a
    /// worker that died closes its own queue so the producer cannot
    /// deadlock against a consumer that will never drain).
    fn push_blocking(&self, item: T) -> (bool, bool) {
        let mut waited = false;
        let mut st = self.state.lock().expect("shard queue poisoned");
        loop {
            if st.closed {
                return (false, waited);
            }
            if st.items.len() < self.capacity {
                st.items.push_back(item);
                drop(st);
                self.not_empty.notify_one();
                return (true, waited);
            }
            waited = true;
            st = self.not_full.wait(st).expect("shard queue poisoned");
        }
    }

    /// Non-blocking push; `false` when full or closed (the caller sheds
    /// the frame).
    fn try_push(&self, item: T) -> bool {
        let mut st = self.state.lock().expect("shard queue poisoned");
        if st.closed || st.items.len() >= self.capacity {
            return false;
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Pop with a bounded wait. Buffered items drain even after close.
    /// The bound is a fixed deadline, not a per-wait timeout: a
    /// spurious (or racing) wakeup re-waits only the *remaining* time,
    /// so a caller waiting out a batch deadline is never stretched past
    /// it.
    fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().expect("shard queue poisoned");
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Pop::Item(item);
            }
            if st.closed {
                return Pop::Closed;
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Pop::TimedOut;
            }
            let (guard, _) = self
                .not_empty
                .wait_timeout(st, remaining)
                .expect("shard queue poisoned");
            st = guard;
        }
    }

    /// Close the queue: no further pushes; pops drain then see `Closed`.
    fn close(&self) {
        let mut st = self.state.lock().expect("shard queue poisoned");
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Closes a queue when dropped. Each worker thread holds one so its
/// queue closes on ANY exit — normal return, error, or panic — because
/// a Block-policy producer must never be left waiting on a consumer
/// that is gone.
struct CloseOnDrop<'a, T>(&'a ShardQueue<T>);

impl<T> Drop for CloseOnDrop<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

// ---------------------------------------------------------------------------
// Sharded engine + streaming handle
// ---------------------------------------------------------------------------

/// The sharded serving tier: a program source fanned out over N
/// queue-fed shards. Constructed low-level over a fixed
/// [`CompiledModel`] or — the canonical path — by
/// [`crate::deploy::Deployment::sharded_engine`] over a publication
/// slot (hot-swaps picked up per shard at batch boundaries).
pub struct ShardedEngine {
    source: EngineSource,
    config: ShardConfig,
    /// Runtime-reconfigurable knobs (overflow / backend / shard count /
    /// generation), shared with every open dispatcher and worker.
    cell: Arc<TierCell>,
    pub metrics: Arc<EngineMetrics>,
    /// Cumulative per-shard counters, shared with every stream this
    /// engine opens (see [`ShardedEngine::snapshot`]). Behind a mutex
    /// only so [`ShardedEngine::reshard`] can replace the vec — workers
    /// hold their own `Arc<ShardTelemetry>` and never touch the lock.
    shard_telemetry: Mutex<Vec<Arc<ShardTelemetry>>>,
    /// Sampled hot-path flight recorder (DESIGN.md §18), shared with
    /// every dispatcher and shard worker this engine spawns. Disabled
    /// by default: each hook is then a single relaxed atomic load.
    tracer: Arc<Tracer>,
}

/// What one shard worker hands back at join time.
struct WorkerResult {
    shard: usize,
    /// (ingest sequence, output word) pairs, scatter-merged at finish.
    outputs: Vec<(u64, u32)>,
    packets: u64,
    batches: u64,
    parse_errors: u64,
    model_version: u64,
}

impl ShardedEngine {
    /// Low-level constructor over a fixed compiled model (tests,
    /// simulator-internals work). Prefer
    /// [`crate::deploy::Deployment::sharded_engine`].
    pub fn new(compiled: CompiledModel, config: ShardConfig) -> Self {
        let source = EngineSource::Static { compiled: Arc::new(compiled), model: None };
        Self {
            shard_telemetry: Mutex::new(Self::fresh_telemetry(&source, config.n_shards)),
            cell: Arc::new(TierCell::new(&config)),
            tracer: Arc::new(Tracer::for_shards(config.n_shards.max(1))),
            source,
            config,
            metrics: Arc::new(EngineMetrics::default()),
        }
    }

    /// One telemetry cell per shard, versions seeded from the source so
    /// a snapshot taken before any batch already reports the published
    /// version instead of a phantom v0 skew.
    fn fresh_telemetry(source: &EngineSource, n: usize) -> Vec<Arc<ShardTelemetry>> {
        (0..n.max(1))
            .map(|_| {
                let t = ShardTelemetry::default();
                t.model_version.store(source.version(), Ordering::Relaxed);
                Arc::new(t)
            })
            .collect()
    }

    /// Attach the source model (enables the `reference` backend on the
    /// low-level path).
    pub fn with_model(mut self, model: BnnModel) -> Self {
        if let EngineSource::Static { model: m, .. } = &mut self.source {
            *m = Some(Arc::new(model));
        }
        self
    }

    /// Sharded engine over a deployment publication slot. Constructed
    /// by [`crate::deploy::Deployment::sharded_engine`].
    pub fn from_slot(
        slot: Arc<ModelSlot>,
        lut: Option<Arc<LutClassifier>>,
        config: ShardConfig,
    ) -> Self {
        let source = EngineSource::Slot { slot, lut };
        Self {
            shard_telemetry: Mutex::new(Self::fresh_telemetry(&source, config.n_shards)),
            cell: Arc::new(TierCell::new(&config)),
            tracer: Arc::new(Tracer::for_shards(config.n_shards.max(1))),
            source,
            config,
            metrics: Arc::new(EngineMetrics::default()),
        }
    }

    /// The engine's hot-path flight recorder. Disabled until someone
    /// calls [`Tracer::set_sample_rate`]; shards beyond the initial
    /// count fold into the existing rings, so a reshard needs no
    /// re-plumbing.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Register this tier's live metrics under `prefix` (canonically
    /// `"tier"`, yielding `tier.shard3.dropped`-style names): the
    /// engine-wide bundle, one series set per shard, and the
    /// reconfigurable knobs as gauges. Values are read at expose time,
    /// so one registration covers the tier's lifetime — except across
    /// [`ShardedEngine::reshard`], which replaces the telemetry cells;
    /// call this again afterwards (stale `shardN` series beyond the new
    /// count are removed first).
    pub fn register_metrics(&self, reg: &MetricsRegistry, prefix: &str) {
        self.metrics.register_into(reg, &format!("{prefix}.engine"));
        reg.remove_prefix(&format!("{prefix}.shard"));
        let telemetry: Vec<Arc<ShardTelemetry>> = self
            .shard_telemetry
            .lock()
            .expect("shard telemetry poisoned")
            .clone();
        for (i, t) in telemetry.into_iter().enumerate() {
            let p = format!("{prefix}.shard{i}");
            let s = Arc::clone(&t);
            reg.counter_fn(&format!("{p}.packets"), move || s.packets.get());
            let s = Arc::clone(&t);
            reg.counter_fn(&format!("{p}.batches"), move || s.batches.get());
            let s = Arc::clone(&t);
            reg.counter_fn(&format!("{p}.parse_errors"), move || s.parse_errors.get());
            let s = Arc::clone(&t);
            reg.counter_fn(&format!("{p}.dropped"), move || s.dropped.get());
            let s = Arc::clone(&t);
            reg.counter_fn(&format!("{p}.backpressure_waits"), move || {
                s.backpressure_waits.get()
            });
            reg.gauge_fn(&format!("{p}.model_version"), move || {
                t.model_version.load(Ordering::Relaxed)
            });
        }
        let cell = Arc::clone(&self.cell);
        reg.gauge_fn(&format!("{prefix}.n_shards"), move || {
            cell.n_shards.load(Ordering::Relaxed) as u64
        });
        let cell = Arc::clone(&self.cell);
        reg.gauge_fn(&format!("{prefix}.generation"), move || {
            cell.generation.load(Ordering::Relaxed)
        });
        let tracer = Arc::clone(&self.tracer);
        reg.counter_fn(&format!("{prefix}.trace.recorded"), move || tracer.recorded());
        let tracer = Arc::clone(&self.tracer);
        reg.gauge_fn(&format!("{prefix}.trace.sample_rate"), move || tracer.sample_rate());
    }

    /// Snapshot of the currently published compiled model.
    pub fn compiled(&self) -> Arc<CompiledModel> {
        self.source.compiled()
    }

    /// Number of shards this engine currently serves with (the target
    /// of the latest [`ShardedEngine::reshard`]; streams opened earlier
    /// keep their shard count until they drain).
    pub fn n_shards(&self) -> usize {
        self.cell.n_shards.load(Ordering::Relaxed).max(1)
    }

    /// The overflow policy live dispatchers currently apply.
    pub fn overflow(&self) -> OverflowPolicy {
        overflow_from_u8(self.cell.overflow.load(Ordering::Relaxed))
    }

    /// Flip the overflow policy at runtime: every live dispatcher
    /// re-reads it with one atomic load per push, so the flip governs
    /// the very next frame. Frames already queued are unaffected —
    /// overflow policy only ever acts on the push side — which is why
    /// the flip is safe mid-stream: it can never un-deliver or reorder
    /// anything, only change whether FUTURE frames wait or shed.
    pub fn set_overflow(&self, policy: OverflowPolicy) {
        self.cell.overflow.store(overflow_to_u8(policy), Ordering::Relaxed);
    }

    /// The backend kind shard workers currently target.
    pub fn backend_kind(&self) -> BackendKind {
        backend_from_u8(self.cell.backend.load(Ordering::Relaxed))
    }

    /// Probe-build a backend of `kind` from the currently published
    /// artifact — the validation both [`ShardedEngine::set_backend`]
    /// and controller-construction policy checks use.
    pub fn check_backend(&self, kind: BackendKind) -> Result<()> {
        self.source.backend(kind).map(|_| ())
    }

    /// Switch every shard's backend at runtime. Validated here by a
    /// probe build (a kind this source cannot construct — `reference`
    /// without a model, `lut` without a table — fails fast and changes
    /// nothing); each worker then picks the new kind up with one atomic
    /// peek per batch and rebuilds from the currently *published*
    /// artifact, the same publication path hot-swaps ride. The switch
    /// lands at batch boundaries only: every batch is classified
    /// entirely by one backend, and all backends are bit-exact on the
    /// same artifact (`tests/prop_batch.rs`), so outputs are unchanged.
    pub fn set_backend(&self, kind: BackendKind) -> Result<()> {
        self.check_backend(kind)?;
        self.cell.backend.store(backend_to_u8(kind), Ordering::Relaxed);
        Ok(())
    }

    /// Reshard the tier to `n` shards via drain-and-rebuild: bumps the
    /// generation (a [`LiveStream`] dispatcher drains its current
    /// stream before the next frame) and installs fresh per-shard
    /// telemetry. The cumulative counters therefore reset across a
    /// reshard — exactly the transition
    /// [`SignalCollector`](crate::controlplane::SignalCollector)
    /// rebaselines on (an empty window, never an underflowed one).
    pub fn reshard(&self, n: usize) -> Result<()> {
        if n == 0 || n > MAX_SHARDS {
            return Err(Error::Config(format!(
                "reshard target {n} out of range (legal: 1..={MAX_SHARDS})"
            )));
        }
        let fresh = Self::fresh_telemetry(&self.source, n);
        let mut telemetry =
            self.shard_telemetry.lock().expect("shard telemetry poisoned");
        self.cell.n_shards.store(n, Ordering::Relaxed);
        *telemetry = fresh;
        // Release pairs with the Acquire in `generation()`: a thread
        // that observes the bumped generation also observes the
        // n_shards store above (the telemetry swap is published by the
        // mutex). Without it, a LiveStream rebuild on weakly-ordered
        // hardware could see the new generation but a stale shard
        // count and silently rebuild at the old width.
        self.cell.generation.fetch_add(1, Ordering::Release);
        Ok(())
    }

    /// Reconfiguration generation (bumped by every reshard).
    pub fn generation(&self) -> u64 {
        self.cell.generation.load(Ordering::Acquire)
    }

    /// Pull a cumulative [`TierSnapshot`]: a few atomic loads over
    /// counters the tier maintains anyway — the control plane's
    /// *collection* step, callable from any thread while streams are
    /// live, with zero work injected on the packet path. Consecutive
    /// snapshots difference into one signal window
    /// ([`crate::controlplane::SignalCollector`]).
    pub fn snapshot(&self) -> TierSnapshot {
        TierSnapshot {
            per_shard: self
                .shard_telemetry
                .lock()
                .expect("shard telemetry poisoned")
                .iter()
                .map(|t| t.counts())
                .collect(),
            classes: self.metrics.classes.snapshot(),
            latency_buckets: self.metrics.batch_latency.bucket_counts(),
        }
    }

    /// Open a streaming ingest handle: spawns the shard workers and
    /// returns the dispatcher-side handle frames are pushed into.
    /// Configuration errors (e.g. a backend that cannot be built)
    /// surface here, before any frame is accepted. The stream is built
    /// against the engine's CURRENT shard count and backend; later
    /// reconfiguration reaches it through the shared [`TierCell`]
    /// (overflow / backend) or a [`LiveStream`] rebuild (reshard).
    pub fn stream(&self) -> Result<ShardedStream> {
        // The telemetry vec is the authoritative shard count: reshard
        // replaces it (to exactly `n` cells) under this same mutex, so
        // deriving `n` from its length can never disagree with the
        // cells the workers are handed — unlike a separate atomic read,
        // which could be stale relative to the vec.
        let telemetry: Vec<Arc<ShardTelemetry>> = self
            .shard_telemetry
            .lock()
            .expect("shard telemetry poisoned")
            .clone();
        let n = telemetry.len();
        let kind = self.backend_kind();
        let compiled = self.source.compiled();
        let modeled_pps = compiled.chip.timing(&compiled.program).pps;
        // Build every backend up front so misconfiguration fails fast.
        let backends: Vec<(Box<dyn InferenceBackend>, u64)> = (0..n)
            .map(|_| self.source.backend(kind))
            .collect::<Result<_>>()?;
        let queues: Vec<Arc<ShardQueue<(u64, Vec<u8>)>>> = (0..n)
            .map(|_| Arc::new(ShardQueue::new(self.config.queue_capacity)))
            .collect();
        let mut workers = Vec::with_capacity(n);
        for (shard, (backend, version)) in backends.into_iter().enumerate() {
            let queue = Arc::clone(&queues[shard]);
            let source = self.source.clone();
            let metrics = Arc::clone(&self.metrics);
            let shard_telemetry = Arc::clone(&telemetry[shard]);
            shard_telemetry.model_version.store(version, Ordering::Relaxed);
            let cell = Arc::clone(&self.cell);
            let policy = self.config.batch;
            let tracer = Arc::clone(&self.tracer);
            workers.push(std::thread::spawn(move || {
                let _close = CloseOnDrop(&*queue);
                shard_worker(
                    shard, &queue, &source, &cell, kind, policy, &metrics,
                    &shard_telemetry, &tracer, backend, version,
                )
            }));
        }
        Ok(ShardedStream {
            queues,
            workers,
            cell: Arc::clone(&self.cell),
            modeled_pps,
            next_seq: 0,
            dropped: vec![0; n],
            waits: vec![0; n],
            started: Instant::now(),
            metrics: Arc::clone(&self.metrics),
            telemetry,
            tracer: Arc::clone(&self.tracer),
        })
    }

    /// Open a reconfiguration-aware streaming handle (see
    /// [`LiveStream`]): same push interface, but the dispatcher also
    /// observes the engine's generation and drains-and-rebuilds across
    /// a reshard, accumulating every epoch's ordered outputs.
    pub fn live_stream(self: &Arc<Self>) -> Result<LiveStream> {
        // Generation is read BEFORE the stream opens: a reshard racing
        // in between leaves the two out of sync, which the first push
        // resolves with a (cheap, empty) drain-and-rebuild — reading it
        // after could instead mask the reshard entirely.
        let seen_generation = self.generation();
        let stream = self.stream()?;
        Ok(LiveStream {
            seen_generation,
            epoch_base: stream.delivered(),
            engine: Arc::clone(self),
            stream: Some(stream),
            epochs: Vec::new(),
            epoch_pushed: 0,
            total_pushed: 0,
        })
    }

    /// Run a whole trace through a fresh set of shard workers; outputs
    /// preserve input order. With [`OverflowPolicy::Block`] this is
    /// bit-exact with [`super::Engine::process_trace`] on the same
    /// backend (`tests/prop_shard.rs`).
    ///
    /// Each frame is copied onto its shard's queue: the workers are
    /// `'static` threads (the streaming API outlives any one trace), so
    /// they cannot borrow the caller's slice the way the scoped-thread
    /// engine does. The copy is a few dozen bytes against a ~µs
    /// inference and is paid identically at every shard count, so
    /// scaling ratios are unaffected.
    pub fn process_trace(&self, packets: &[Vec<u8>]) -> Result<ShardedReport> {
        let mut stream = self.stream()?;
        for pkt in packets {
            if let Err(e) = stream.push(pkt.clone()) {
                // A shard worker died: close the surviving queues and
                // join everyone before surfacing the failure, so no
                // worker thread is left parked.
                let _ = stream.finish();
                return Err(e);
            }
        }
        stream.finish()
    }
}

/// One shard's pull loop: deadline-aware pops feeding the shard's
/// [`Batcher`]. This is the stranded-tail fix — the wait is bounded by
/// `time_until_deadline`, so a stalled (but open) stream still has its
/// partial batch flushed at the `max_delay` bound instead of sitting
/// until close.
// Worker threads receive each shared handle individually (they are
// moved into the spawn closure); bundling them into a struct would
// just relocate the argument list.
#[allow(clippy::too_many_arguments)]
fn shard_worker(
    shard: usize,
    queue: &ShardQueue<(u64, Vec<u8>)>,
    source: &EngineSource,
    cell: &TierCell,
    mut kind: BackendKind,
    policy: BatchPolicy,
    metrics: &EngineMetrics,
    telemetry: &ShardTelemetry,
    tracer: &Tracer,
    mut backend: Box<dyn InferenceBackend>,
    mut version: u64,
) -> Result<WorkerResult> {
    /// Idle wait between queue peeks when no tail is pending (close is
    /// condvar-notified, so this only bounds spurious wakeups).
    const IDLE_WAIT: Duration = Duration::from_millis(25);

    let mut outputs = Vec::new();
    let mut out_buf = Vec::new();
    let mut batcher: Batcher<(u64, Vec<u8>)> = Batcher::new(policy);
    let mut packets = 0u64;
    let mut batches = 0u64;
    let mut retired_errs = 0u64;

    let run = |batch: Batch<(u64, Vec<u8>)>,
               backend: &mut Box<dyn InferenceBackend>,
               kind: &mut BackendKind,
               version: &mut u64,
               retired_errs: &mut u64,
               outputs: &mut Vec<(u64, u32)>,
               out_buf: &mut Vec<u32>|
     -> Result<()> {
        // Runtime backend switch: one atomic kind peek per batch. A
        // switch rebuilds from the currently PUBLISHED artifact (the
        // same slot hot-swaps publish through), so it subsumes any
        // pending version refresh; the batch about to run is the first
        // one the new backend serves — never a torn batch.
        let want = backend_from_u8(cell.backend.load(Ordering::Relaxed));
        if want != *kind {
            *retired_errs += backend.stats().parse_errors;
            let (fresh, v) = source.backend(want)?;
            *backend = fresh;
            *version = v;
            *kind = want;
        }
        // Hot-swap pickup: one atomic version peek per batch (the
        // protocol itself lives on [`EngineSource::refresh`], shared
        // with the engine workers).
        let version_before = *version;
        source.refresh(*kind, backend, version, retired_errs)?;
        telemetry.model_version.store(*version, Ordering::Relaxed);
        if *version != version_before {
            tracer.record(shard, EventKind::SwapObserved, version_before, *version);
        }
        tracer.record(shard, EventKind::BatchDispatch, batch.packets.len() as u64, *version);
        let t0 = Instant::now();
        metrics.packets_in.add(batch.packets.len() as u64);
        let refs: Vec<&[u8]> = batch.packets.iter().map(|(_, p)| p.as_slice()).collect();
        let errs_before = backend.stats().parse_errors;
        backend.run_batch(&refs, out_buf)?;
        let errs = backend.stats().parse_errors.saturating_sub(errs_before);
        metrics.parse_errors.add(errs);
        metrics.packets_dropped.add(errs);
        metrics
            .packets_classified
            .add(refs.len() as u64 - errs.min(refs.len() as u64));
        let mut class_counts = [0u64; CLASS_BUCKETS];
        for (k, (seq, _)) in batch.packets.iter().enumerate() {
            let word = out_buf.get(k).copied().unwrap_or(0);
            class_counts[ClassMix::bucket_of(word)] += 1;
            outputs.push((*seq, word));
        }
        metrics.classes.add(&class_counts);
        telemetry.packets.add(refs.len() as u64);
        telemetry.batches.inc();
        telemetry.parse_errors.add(errs);
        let elapsed = t0.elapsed();
        metrics.batch_latency.record(elapsed);
        tracer.record(
            shard,
            EventKind::BackendRun,
            refs.len() as u64,
            elapsed.as_nanos().min(u64::MAX as u128) as u64,
        );
        Ok(())
    };

    loop {
        let wait = batcher.time_until_deadline().unwrap_or(IDLE_WAIT);
        match queue.pop_timeout(wait) {
            Pop::Item(item) => {
                packets += 1;
                if let Some(batch) = batcher.push(item) {
                    batches += 1;
                    run(
                        batch,
                        &mut backend,
                        &mut kind,
                        &mut version,
                        &mut retired_errs,
                        &mut outputs,
                        &mut out_buf,
                    )?;
                }
            }
            Pop::TimedOut => {
                if let Some(batch) = batcher.poll_deadline() {
                    batches += 1;
                    run(
                        batch,
                        &mut backend,
                        &mut kind,
                        &mut version,
                        &mut retired_errs,
                        &mut outputs,
                        &mut out_buf,
                    )?;
                }
            }
            Pop::Closed => {
                if let Some(batch) = batcher.flush() {
                    batches += 1;
                    run(
                        batch,
                        &mut backend,
                        &mut kind,
                        &mut version,
                        &mut retired_errs,
                        &mut outputs,
                        &mut out_buf,
                    )?;
                }
                break;
            }
        }
    }
    Ok(WorkerResult {
        shard,
        outputs,
        packets,
        batches,
        parse_errors: retired_errs + backend.stats().parse_errors,
        model_version: version,
    })
}

/// Dispatcher-side streaming handle: frames pushed here are
/// flow-hashed onto their shard's queue; [`ShardedStream::finish`]
/// closes the queues, joins the workers, and merges the report.
/// Dropping the handle without `finish` (an error/unwind path) closes
/// the queues too, so the workers drain and exit instead of parking
/// forever — but only `finish` returns their outputs.
pub struct ShardedStream {
    queues: Vec<Arc<ShardQueue<(u64, Vec<u8>)>>>,
    workers: Vec<JoinHandle<Result<WorkerResult>>>,
    /// Shared tier knobs: the dispatcher re-reads the overflow policy
    /// here on EVERY push, which is what makes a runtime flip land on
    /// the next frame.
    cell: Arc<TierCell>,
    modeled_pps: f64,
    /// Ingest sequence number: the output position of the next frame.
    next_seq: u64,
    /// Per-shard frames shed at a full queue.
    dropped: Vec<u64>,
    /// Per-shard producer waits on a full queue (backpressure events).
    waits: Vec<u64>,
    started: Instant,
    pub metrics: Arc<EngineMetrics>,
    /// Cumulative per-shard counters shared with the owning engine
    /// (drop/backpressure events are dispatcher-side, so they are
    /// recorded here as well as in the per-run vecs above).
    telemetry: Vec<Arc<ShardTelemetry>>,
    /// Shared flight recorder (disabled: one relaxed load per hook).
    tracer: Arc<Tracer>,
}

impl ShardedStream {
    /// Number of shards this stream dispatches over.
    pub fn n_shards(&self) -> usize {
        self.queues.len()
    }

    /// Frames this stream's telemetry cells have retired (classified +
    /// shed). Cumulative — the cells are shared with every stream the
    /// owning engine opened since its last reshard — so callers diff
    /// against a baseline ([`LiveStream::quiesce`]).
    fn delivered(&self) -> u64 {
        self.telemetry.iter().map(|t| t.packets.get() + t.dropped.get()).sum()
    }

    /// Ingest one frame. The frame's output position is its push order;
    /// a frame shed under [`OverflowPolicy::Drop`] keeps its position
    /// with output word 0.
    pub fn push(&mut self, pkt: Vec<u8>) -> Result<()> {
        let hash = flow_hash(&pkt);
        let shard = (hash % self.queues.len() as u64) as usize;
        let seq = self.next_seq;
        self.next_seq += 1;
        let len = pkt.len() as u64;
        // Flight-recorder hooks: each `record` is one relaxed atomic
        // load when tracing is off (DESIGN.md §18) — nothing else may
        // be added on this path.
        self.tracer.record(shard, EventKind::FrameIngress, hash, len);
        // One relaxed load per push: the control plane can flip the
        // policy mid-stream and the very next frame honors it.
        match overflow_from_u8(self.cell.overflow.load(Ordering::Relaxed)) {
            OverflowPolicy::Block => {
                let (pushed, waited) = self.queues[shard].push_blocking((seq, pkt));
                if waited {
                    self.waits[shard] += 1;
                    self.telemetry[shard].backpressure_waits.inc();
                    self.tracer.record(shard, EventKind::Backpressure, hash, len);
                }
                if !pushed {
                    return Err(Error::Config(format!(
                        "shard {shard} worker terminated; stream cannot accept frames"
                    )));
                }
            }
            OverflowPolicy::Drop => {
                if !self.queues[shard].try_push((seq, pkt)) {
                    self.dropped[shard] += 1;
                    self.telemetry[shard].dropped.inc();
                    self.tracer.record(shard, EventKind::Drop, hash, len);
                }
            }
        }
        Ok(())
    }

    /// End of stream: close every queue (workers drain, flush their
    /// tails, and exit), join, and merge the per-shard results into one
    /// report with outputs in ingest order.
    pub fn finish(mut self) -> Result<ShardedReport> {
        for q in &self.queues {
            q.close();
        }
        let n_packets = self.next_seq as usize;
        let mut outputs = vec![0u32; n_packets];
        let mut per_shard: Vec<ShardStats> = (0..self.queues.len())
            .map(|i| ShardStats {
                shard: i,
                dropped: self.dropped[i],
                backpressure_waits: self.waits[i],
                ..ShardStats::default()
            })
            .collect();
        let mut parse_errors = 0u64;
        // Join EVERY worker before surfacing a failure: the queues are
        // closed, so survivors drain and exit; erroring out mid-join
        // would leave them detached, still mutating the shared metrics
        // behind the caller's back.
        let mut first_err = None;
        for w in std::mem::take(&mut self.workers) {
            let r = match w.join().expect("shard worker panicked") {
                Ok(r) => r,
                Err(e) => {
                    first_err.get_or_insert(e);
                    continue;
                }
            };
            for (seq, word) in &r.outputs {
                outputs[*seq as usize] = *word;
            }
            parse_errors += r.parse_errors;
            let st = &mut per_shard[r.shard];
            st.packets = r.packets;
            st.batches = r.batches;
            st.parse_errors = r.parse_errors;
            st.model_version = r.model_version;
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let elapsed = self.started.elapsed().as_secs_f64();
        let version_min = per_shard.iter().map(|s| s.model_version).min().unwrap_or(0);
        let version_max = per_shard.iter().map(|s| s.model_version).max().unwrap_or(0);
        Ok(ShardedReport {
            outputs,
            n_packets,
            sim_pps: n_packets as f64 / elapsed.max(1e-12),
            modeled_pps: self.modeled_pps,
            parse_errors,
            dropped: self.dropped.iter().sum(),
            backend: backend_from_u8(self.cell.backend.load(Ordering::Relaxed))
                .name(),
            per_shard,
            version_min,
            version_max,
        })
    }
}

impl Drop for ShardedStream {
    fn drop(&mut self) {
        // `finish` consumes self and has already closed these (close is
        // idempotent); on an early drop — error return or unwind between
        // `push` and `finish` — this is what lets the shard workers
        // drain and exit instead of leaking, parked on their queues.
        for q in &self.queues {
            q.close();
        }
    }
}

// ---------------------------------------------------------------------------
// Live (reconfiguration-aware) streaming
// ---------------------------------------------------------------------------

/// Merged result of a [`LiveStream`] run: every epoch's outputs
/// concatenated in global ingest order, plus the per-epoch reports (one
/// epoch per tier configuration the stream served under).
#[derive(Clone, Debug)]
pub struct LiveReport {
    /// Output word per pushed frame, global ingest order; 0 for
    /// malformed or shed frames.
    pub outputs: Vec<u32>,
    pub n_packets: usize,
    pub parse_errors: u64,
    /// Frames shed across every epoch ([`OverflowPolicy::Drop`]).
    pub dropped: u64,
    /// One [`ShardedReport`] per epoch, in serving order. A run that
    /// was never resharded has exactly one.
    pub epochs: Vec<ShardedReport>,
}

impl LiveReport {
    /// Drain-and-rebuild cycles the stream went through.
    pub fn reconfigs(&self) -> usize {
        self.epochs.len().saturating_sub(1)
    }

    /// Frames actually delivered to (and classified by) shard workers.
    pub fn delivered(&self) -> u64 {
        self.epochs
            .iter()
            .map(|e| e.per_shard.iter().map(|s| s.packets).sum::<u64>())
            .sum()
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "live stream: {} packets over {} epoch(s) ({} reconfig) — \
             parse_errors={} dropped={}\n",
            self.n_packets,
            self.epochs.len(),
            self.reconfigs(),
            self.parse_errors,
            self.dropped,
        );
        for (i, e) in self.epochs.iter().enumerate() {
            s.push_str(&format!(
                "  epoch {i}: {} packets, {} shards, {} backend, dropped={}\n",
                e.n_packets,
                e.per_shard.len(),
                e.backend,
                e.dropped,
            ));
        }
        s
    }
}

/// Reconfiguration-aware streaming handle: pushes go to an inner
/// [`ShardedStream`], and on every push the dispatcher peeks the
/// engine's generation — when a reshard was requested it **drains**
/// the current stream to completion (every queued frame classified,
/// workers joined) and opens a fresh one against the new configuration
/// before accepting the frame. That barrier is the whole correctness
/// argument: no frame is in flight across the boundary, so every flow
/// is served old-tier-then-new-tier (never interleaved) and the
/// concatenated epoch outputs are in global ingest order.
///
/// Overflow flips and backend switches need no rebuild at all — they
/// propagate through the shared [`TierCell`] to the current stream's
/// dispatcher and workers directly.
pub struct LiveStream {
    engine: Arc<ShardedEngine>,
    stream: Option<ShardedStream>,
    seen_generation: u64,
    /// Finished epochs, oldest first.
    epochs: Vec<ShardedReport>,
    /// Frames pushed into the current epoch's stream.
    epoch_pushed: u64,
    /// Engine `delivered_total` at the current epoch's start.
    epoch_base: u64,
    total_pushed: u64,
}

impl LiveStream {
    /// Frames pushed so far (across every epoch).
    pub fn pushed(&self) -> u64 {
        self.total_pushed
    }

    /// Completed drain-and-rebuild cycles so far.
    pub fn reconfigs(&self) -> usize {
        self.epochs.len()
    }

    /// Ingest one frame, draining and rebuilding first if the engine
    /// was resharded since the last push.
    pub fn push(&mut self, pkt: Vec<u8>) -> Result<()> {
        if self.engine.generation() != self.seen_generation {
            self.rebuild()?;
        }
        self.epoch_pushed += 1;
        self.total_pushed += 1;
        self.stream.as_mut().expect("live stream open").push(pkt)
    }

    /// Drain the current epoch and open the next one against the
    /// engine's current configuration.
    fn rebuild(&mut self) -> Result<()> {
        if let Some(s) = self.stream.take() {
            self.epochs.push(s.finish()?);
        }
        // Generation read before the open (see live_stream), but
        // COMMITTED only after it succeeds: a failed open must leave
        // the generations out of sync so the next push retries the
        // rebuild (returning its error) instead of hitting the
        // `stream: None` expect below.
        let generation = self.engine.generation();
        let stream = self.engine.stream()?;
        self.seen_generation = generation;
        self.epoch_base = stream.delivered();
        self.epoch_pushed = 0;
        self.stream = Some(stream);
        Ok(())
    }

    /// Wait (bounded by `timeout`) until every frame pushed into the
    /// current epoch has been retired by the tier — classified or
    /// counted as shed. Lets a paced serving loop align control-plane
    /// snapshots with window boundaries; returns `false` on timeout.
    pub fn quiesce(&self, timeout: Duration) -> bool {
        let stream = match &self.stream {
            Some(s) => s,
            None => return true,
        };
        let deadline = Instant::now() + timeout;
        loop {
            let retired = stream.delivered().saturating_sub(self.epoch_base);
            if retired >= self.epoch_pushed {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_micros(500));
        }
    }

    /// End of stream: drain the final epoch and merge every epoch's
    /// ordered outputs.
    pub fn finish(mut self) -> Result<LiveReport> {
        if let Some(s) = self.stream.take() {
            self.epochs.push(s.finish()?);
        }
        let mut outputs = Vec::with_capacity(self.total_pushed as usize);
        let mut parse_errors = 0u64;
        let mut dropped = 0u64;
        for e in &self.epochs {
            outputs.extend_from_slice(&e.outputs);
            parse_errors += e.parse_errors;
            dropped += e.dropped;
        }
        Ok(LiveReport {
            outputs,
            n_packets: self.total_pushed as usize,
            parse_errors,
            dropped,
            epochs: self.epochs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{self, BnnModel, PackedBits};
    use crate::compiler::{Compiler, CompilerOptions, InputEncoding};
    use crate::net::packet::{PacketBuilder, IPV4_SRC_OFFSET};
    use crate::net::{TraceGenerator, TraceKind};
    use crate::rmt::ChipConfig;

    fn compiled_for(model: &BnnModel) -> CompiledModel {
        let opts = CompilerOptions {
            input: InputEncoding::BigEndianField { offset: IPV4_SRC_OFFSET },
            ..Default::default()
        };
        Compiler::new(ChipConfig::rmt(), opts).compile(model).unwrap()
    }

    #[test]
    fn sharded_outputs_preserve_order_and_match_reference() {
        let model = BnnModel::random(32, &[16, 1], 51);
        for n_shards in [1usize, 3] {
            let engine = ShardedEngine::new(
                compiled_for(&model),
                ShardConfig { n_shards, ..ShardConfig::default() },
            );
            let mut gen = TraceGenerator::new(23);
            let trace = gen.generate(&TraceKind::UniformIps, 300);
            let report = engine.process_trace(&trace.packets).unwrap();
            assert_eq!(report.outputs.len(), 300);
            assert_eq!(report.per_shard.len(), n_shards);
            assert_eq!(report.dropped, 0, "Block policy never sheds");
            assert_eq!(
                report.per_shard.iter().map(|s| s.packets).sum::<u64>(),
                300
            );
            for (i, &key) in trace.keys.iter().enumerate() {
                let expect =
                    bnn::forward(&model, &PackedBits::from_u32(key)).get(0) as u32;
                assert_eq!(report.outputs[i], expect, "{n_shards} shards pkt {i}");
            }
        }
    }

    #[test]
    fn tracer_records_the_hot_path_and_registry_exposes_the_tier() {
        let model = BnnModel::random(32, &[16], 58);
        let engine = ShardedEngine::new(
            compiled_for(&model),
            ShardConfig { n_shards: 2, ..ShardConfig::default() },
        );
        // Disabled by default: a run records nothing.
        let mut gen = TraceGenerator::new(31);
        let trace = gen.generate(&TraceKind::UniformIps, 200);
        engine.process_trace(&trace.packets).unwrap();
        assert_eq!(engine.tracer().recorded(), 0, "tracing off by default");

        // Full rate: every ingress frame and every batch is recorded.
        engine.tracer().set_sample_rate(1);
        engine.process_trace(&trace.packets).unwrap();
        let events = engine.tracer().dump();
        assert!(!events.is_empty());
        let ingress =
            events.iter().filter(|e| e.kind == EventKind::FrameIngress).count();
        assert!(ingress > 0, "ingress events recorded");
        assert!(
            events.iter().any(|e| e.kind == EventKind::BackendRun),
            "backend-run events recorded: {events:?}"
        );

        // Registry exposition covers engine, per-shard, and tier knobs.
        let reg = MetricsRegistry::new();
        engine.register_metrics(&reg, "tier");
        let exposed = reg.expose();
        for series in [
            "tier_engine_packets_in",
            "tier_engine_batch_latency_count",
            "tier_shard0_packets",
            "tier_shard1_dropped",
            "tier_n_shards 2",
            "tier_trace_sample_rate 1",
        ] {
            assert!(exposed.contains(series), "missing {series}:\n{exposed}");
        }
        // Shard series read the live cells: both shards' packets sum to
        // the delivered total (two runs of 200, Block policy).
        let t0: u64 = exposed
            .lines()
            .find(|l| l.starts_with("tier_shard0_packets "))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap();
        let t1: u64 = exposed
            .lines()
            .find(|l| l.starts_with("tier_shard1_packets "))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap();
        assert_eq!(t0 + t1, 400);

        // The per-run report exposes through the same registry format.
        let report = engine.process_trace(&trace.packets).unwrap();
        let exposed = report.expose();
        assert!(exposed.contains("tier_packets 200"), "{exposed}");
        assert!(exposed.contains("# TYPE tier_shard0_packets counter"), "{exposed}");
    }

    #[test]
    fn flow_affinity_is_per_shard_stable() {
        // Every frame of one flow lands on the same shard: with a
        // single-flow trace, exactly one shard sees packets.
        let model = BnnModel::random(32, &[16], 52);
        let engine = ShardedEngine::new(
            compiled_for(&model),
            ShardConfig { n_shards: 4, ..ShardConfig::default() },
        );
        let packets: Vec<Vec<u8>> = (0..64)
            .map(|i| {
                PacketBuilder::default()
                    .src_ip(0x0A000001)
                    .build_activations(&[i as u32])
            })
            .collect();
        let report = engine.process_trace(&packets).unwrap();
        let loaded: Vec<&ShardStats> =
            report.per_shard.iter().filter(|s| s.packets > 0).collect();
        assert_eq!(loaded.len(), 1, "one flow, one shard");
        assert_eq!(loaded[0].packets, 64);
    }

    #[test]
    fn drop_policy_sheds_with_exact_accounting() {
        let model = BnnModel::random(32, &[16], 53);
        let engine = ShardedEngine::new(
            compiled_for(&model),
            ShardConfig {
                n_shards: 2,
                queue_capacity: 1,
                overflow: OverflowPolicy::Drop,
                // A 1-frame queue against a fast producer makes drops
                // likely, but none are guaranteed on any particular run
                // — the accounting identity is what's asserted.
                ..ShardConfig::default()
            },
        );
        let mut gen = TraceGenerator::new(29);
        let trace = gen.generate(&TraceKind::UniformIps, 2000);
        let report = engine.process_trace(&trace.packets).unwrap();
        assert_eq!(report.outputs.len(), 2000);
        let delivered: u64 = report.per_shard.iter().map(|s| s.packets).sum();
        assert_eq!(
            delivered + report.dropped,
            2000,
            "every frame is either delivered or counted as shed"
        );
        assert_eq!(
            report.dropped,
            report.per_shard.iter().map(|s| s.dropped).sum::<u64>()
        );
    }

    #[test]
    fn stalled_stream_flushes_partial_batch_by_deadline() {
        // Regression (ISSUE 3 satellite): a worker loop that only wakes
        // on new items strands a sub-`max_size` tail while the stream
        // stalls. The deadline-aware pull loop must classify the tail
        // within ~max_delay even though the stream stays open.
        let model = BnnModel::random(32, &[16], 54);
        let engine = ShardedEngine::new(
            compiled_for(&model),
            ShardConfig {
                n_shards: 2,
                batch: BatchPolicy {
                    max_size: 64,
                    max_delay: Duration::from_millis(5),
                },
                ..ShardConfig::default()
            },
        );
        let mut stream = engine.stream().unwrap();
        let mut gen = TraceGenerator::new(31);
        let trace = gen.generate(&TraceKind::UniformIps, 5);
        for pkt in &trace.packets {
            stream.push(pkt.clone()).unwrap();
        }
        // The stream now stalls below max_size, without closing.
        let t0 = Instant::now();
        while engine.metrics.packets_classified.get() < 5 {
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "stranded tail: {} of 5 classified while the stream stalls",
                engine.metrics.packets_classified.get()
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        let report = stream.finish().unwrap();
        assert_eq!(report.n_packets, 5);
        assert_eq!(report.per_shard.iter().map(|s| s.packets).sum::<u64>(), 5);
    }

    #[test]
    fn empty_tier_imbalance_is_zero_not_nan() {
        // Regression (ISSUE 4 satellite): an idle tier — zero frames
        // served, or a hand-built report with no shards at all — must
        // report imbalance 0.0, never NaN (a NaN would poison every
        // controller threshold comparison downstream).
        let model = BnnModel::random(32, &[16], 56);
        let engine = ShardedEngine::new(
            compiled_for(&model),
            ShardConfig { n_shards: 3, ..ShardConfig::default() },
        );
        let report = engine.process_trace(&[]).unwrap();
        assert_eq!(report.n_packets, 0);
        assert_eq!(report.imbalance(), 0.0);
        assert!(report.imbalance().is_finite());

        let degenerate = ShardedReport {
            outputs: Vec::new(),
            n_packets: 0,
            sim_pps: 0.0,
            modeled_pps: 0.0,
            parse_errors: 0,
            dropped: 0,
            backend: "batched",
            per_shard: Vec::new(),
            version_min: 0,
            version_max: 0,
        };
        assert_eq!(degenerate.imbalance(), 0.0);
    }

    #[test]
    fn snapshots_accumulate_across_traces_and_count_classes() {
        let model = BnnModel::random(32, &[16, 1], 57);
        let engine = ShardedEngine::new(
            compiled_for(&model),
            ShardConfig { n_shards: 2, ..ShardConfig::default() },
        );
        let before = engine.snapshot();
        assert_eq!(before.per_shard.len(), 2);
        assert_eq!(before.per_shard.iter().map(|s| s.packets).sum::<u64>(), 0);
        assert_eq!(before.classes.iter().sum::<u64>(), 0);

        let mut gen = TraceGenerator::new(58);
        let trace = gen.generate(&TraceKind::UniformIps, 200);
        let report = engine.process_trace(&trace.packets).unwrap();
        let mid = engine.snapshot();
        assert_eq!(mid.per_shard.iter().map(|s| s.packets).sum::<u64>(), 200);
        assert_eq!(mid.classes.iter().sum::<u64>(), 200);
        // The class histogram agrees with the merged outputs.
        let ones = report.outputs.iter().filter(|&&w| w & 1 == 1).count() as u64;
        assert_eq!(mid.classes[1], ones);
        assert_eq!(mid.classes[0], 200 - ones);
        assert!(mid.latency_buckets.iter().sum::<u64>() > 0);

        // A second trace on the same engine keeps accumulating — the
        // diff of consecutive snapshots isolates the window.
        engine.process_trace(&trace.packets).unwrap();
        let after = engine.snapshot();
        assert_eq!(after.per_shard.iter().map(|s| s.packets).sum::<u64>(), 400);
        let window: u64 = after
            .per_shard
            .iter()
            .zip(&mid.per_shard)
            .map(|(a, b)| a.packets - b.packets)
            .sum();
        assert_eq!(window, 200);
    }

    #[test]
    fn overflow_flip_lands_on_the_next_push_with_exact_accounting() {
        // The dispatcher re-reads the policy per push, so a Block → Drop
        // flip mid-stream governs subsequent frames; under either
        // policy every frame is delivered or counted as shed.
        let model = BnnModel::random(32, &[16], 61);
        let engine = ShardedEngine::new(
            compiled_for(&model),
            ShardConfig {
                n_shards: 2,
                queue_capacity: 1,
                ..ShardConfig::default()
            },
        );
        assert_eq!(engine.overflow(), OverflowPolicy::Block);
        let mut stream = engine.stream().unwrap();
        let mut gen = TraceGenerator::new(62);
        let trace = gen.generate(&TraceKind::UniformIps, 2000);
        for (i, pkt) in trace.packets.iter().enumerate() {
            if i == 100 {
                engine.set_overflow(OverflowPolicy::Drop);
            }
            stream.push(pkt.clone()).unwrap();
        }
        assert_eq!(engine.overflow(), OverflowPolicy::Drop);
        let report = stream.finish().unwrap();
        assert_eq!(report.outputs.len(), 2000);
        let delivered: u64 = report.per_shard.iter().map(|s| s.packets).sum();
        assert_eq!(delivered + report.dropped, 2000, "exact shed accounting");
        // Frames served before the flip were under Block: none shed.
        for (i, &key) in trace.keys.iter().take(100).enumerate() {
            let expect =
                bnn::forward(&model, &PackedBits::from_u32(key)).get(0) as u32;
            assert_eq!(report.outputs[i], expect, "pre-flip pkt {i}");
        }
    }

    #[test]
    fn backend_switch_mid_stream_is_bit_exact_and_validated() {
        let model = BnnModel::random(32, &[16, 1], 63);
        let engine = ShardedEngine::new(
            compiled_for(&model),
            ShardConfig { n_shards: 2, ..ShardConfig::default() },
        )
        .with_model(model.clone());
        // A kind this source cannot build fails fast, changing nothing.
        assert!(
            ShardedEngine::new(compiled_for(&model), ShardConfig::default())
                .set_backend(BackendKind::Reference)
                .is_err(),
            "reference backend needs the source model"
        );
        assert_eq!(engine.backend_kind(), BackendKind::Batched);

        let mut stream = engine.stream().unwrap();
        let mut gen = TraceGenerator::new(64);
        let trace = gen.generate(&TraceKind::UniformIps, 400);
        for (i, pkt) in trace.packets.iter().enumerate() {
            if i == 200 {
                engine.set_backend(BackendKind::Scalar).unwrap();
            }
            stream.push(pkt.clone()).unwrap();
        }
        assert_eq!(engine.backend_kind(), BackendKind::Scalar);
        let report = stream.finish().unwrap();
        assert_eq!(report.backend, "scalar", "report names the current kind");
        for (i, &key) in trace.keys.iter().enumerate() {
            let expect =
                bnn::forward(&model, &PackedBits::from_u32(key)).get(0) as u32;
            assert_eq!(report.outputs[i], expect, "pkt {i} across the switch");
        }
    }

    #[test]
    fn reshard_drains_and_rebuilds_preserving_order_and_outputs() {
        let model = BnnModel::random(32, &[16, 1], 65);
        let engine = Arc::new(ShardedEngine::new(
            compiled_for(&model),
            ShardConfig { n_shards: 2, ..ShardConfig::default() },
        ));
        assert!(engine.reshard(0).is_err(), "reshard 0 out of range");
        let err = engine.reshard(MAX_SHARDS + 1).unwrap_err().to_string();
        assert!(err.contains("1..="), "range enumerated: {err}");

        let mut stream = engine.live_stream().unwrap();
        let mut gen = TraceGenerator::new(66);
        let trace = gen.generate(&TraceKind::UniformIps, 600);
        for (i, pkt) in trace.packets.iter().enumerate() {
            if i == 300 {
                engine.reshard(4).unwrap();
                assert_eq!(engine.n_shards(), 4);
            }
            stream.push(pkt.clone()).unwrap();
        }
        assert_eq!(stream.pushed(), 600);
        assert_eq!(stream.reconfigs(), 1, "one drain-and-rebuild");
        let report = stream.finish().unwrap();
        assert_eq!(report.n_packets, 600);
        assert_eq!(report.reconfigs(), 1);
        assert_eq!(report.epochs.len(), 2);
        assert_eq!(report.epochs[0].per_shard.len(), 2);
        assert_eq!(report.epochs[1].per_shard.len(), 4);
        assert_eq!(report.dropped, 0, "Block policy across both epochs");
        assert_eq!(report.delivered(), 600);
        // Global ingest order, bit-exact across the boundary.
        for (i, &key) in trace.keys.iter().enumerate() {
            let expect =
                bnn::forward(&model, &PackedBits::from_u32(key)).get(0) as u32;
            assert_eq!(report.outputs[i], expect, "pkt {i}");
        }
        assert!(report.render().contains("epoch 1"));
    }

    #[test]
    fn live_stream_quiesce_waits_for_retirement() {
        let model = BnnModel::random(32, &[16], 67);
        let engine = Arc::new(ShardedEngine::new(
            compiled_for(&model),
            ShardConfig { n_shards: 2, ..ShardConfig::default() },
        ));
        let mut stream = engine.live_stream().unwrap();
        let mut gen = TraceGenerator::new(68);
        let trace = gen.generate(&TraceKind::UniformIps, 50);
        for pkt in &trace.packets {
            stream.push(pkt.clone()).unwrap();
        }
        assert!(
            stream.quiesce(Duration::from_secs(5)),
            "all pushed frames retire"
        );
        assert!(engine.metrics.packets_classified.get() >= 50);
        let report = stream.finish().unwrap();
        assert_eq!(report.n_packets, 50);
    }

    #[test]
    fn version_skew_fields_are_sane_on_the_static_path() {
        let model = BnnModel::random(32, &[16], 55);
        let engine = ShardedEngine::new(compiled_for(&model), ShardConfig::default());
        let mut gen = TraceGenerator::new(37);
        let trace = gen.generate(&TraceKind::UniformIps, 100);
        let report = engine.process_trace(&trace.packets).unwrap();
        // Fixed-program source: every shard serves version 0, no skew.
        assert_eq!((report.version_min, report.version_max), (0, 0));
        assert!(report.render().contains("shard 0"));
        assert!(report.imbalance() >= 1.0);
    }
}
