//! Trusted reference forward pass.
//!
//! This is the crate's ground truth: it evaluates the BNN with ordinary
//! CPU arithmetic (`u32::count_ones`), independent of both the RMT
//! pipeline implementation and the PJRT artifact. All three must agree
//! bit-for-bit (integration tests + proptest enforce this).

use super::bitpack::PackedBits;
use super::model::BnnModel;

/// Per-layer record of a forward pass.
#[derive(Clone, Debug)]
pub struct LayerTrace {
    /// XNOR-popcount pre-activation per neuron (0..=in_bits).
    pub popcounts: Vec<u32>,
    /// Packed sign bits — the layer output the folding step builds.
    pub signs: PackedBits,
}

/// One layer: packed activations -> (popcounts, packed sign bits).
pub fn layer_forward(layer: &crate::bnn::BnnLayer, x: &PackedBits) -> LayerTrace {
    assert_eq!(
        x.len(),
        layer.in_bits,
        "activation width {} != layer in_bits {}",
        x.len(),
        layer.in_bits
    );
    let mut popcounts = Vec::with_capacity(layer.n_neurons());
    let mut signs = PackedBits::zeros(layer.n_neurons());
    for (j, w) in layer.neurons.iter().enumerate() {
        let pop = x.agreement(w);
        popcounts.push(pop);
        if pop >= layer.threshold {
            signs.set(j, true);
        }
    }
    LayerTrace { popcounts, signs }
}

/// Full forward pass; returns only the final layer's packed sign bits.
pub fn forward(model: &BnnModel, x: &PackedBits) -> PackedBits {
    forward_trace(model, x).last().unwrap().signs.clone()
}

/// Full forward pass with per-layer traces (for cross-checking every
/// intermediate against the pipeline and the oracle).
pub fn forward_trace(model: &BnnModel, x: &PackedBits) -> Vec<LayerTrace> {
    let mut traces = Vec::with_capacity(model.layers.len());
    let mut act = x.clone();
    for layer in &model.layers {
        let t = layer_forward(layer, &act);
        act = t.signs.clone();
        traces.push(t);
    }
    traces
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{BnnLayer, BnnModel, BnnSpec};

    /// Naive float ±1 implementation to check the packed one against.
    fn float_layer(x: &PackedBits, rows: &[PackedBits]) -> Vec<u8> {
        rows.iter()
            .map(|w| {
                let acc: i64 = (0..x.len())
                    .map(|i| {
                        let xv = if x.get(i) { 1i64 } else { -1 };
                        let wv = if w.get(i) { 1i64 } else { -1 };
                        xv * wv
                    })
                    .sum();
                (acc >= 0) as u8
            })
            .collect()
    }

    #[test]
    fn packed_equals_float_reference() {
        let mut rng = crate::util::rng::Rng::seed_from_u64(3);
        for n in [16usize, 32, 128] {
            let x = PackedBits::random(n, &mut rng);
            let rows: Vec<PackedBits> =
                (0..20).map(|_| PackedBits::random(n, &mut rng)).collect();
            let layer = BnnLayer::new(n, rows.clone()).unwrap();
            let t = layer_forward(&layer, &x);
            assert_eq!(t.signs.to_bits(), float_layer(&x, &rows), "n={n}");
        }
    }

    #[test]
    fn popcount_range_and_threshold() {
        let layer = BnnLayer::new(
            32,
            vec![PackedBits::from_u32(0), PackedBits::from_u32(u32::MAX)],
        )
        .unwrap();
        let x = PackedBits::from_u32(u32::MAX);
        let t = layer_forward(&layer, &x);
        assert_eq!(t.popcounts, vec![0, 32]);
        assert_eq!(t.signs.to_bits(), vec![0, 1]);
    }

    #[test]
    fn multilayer_chaining_widths() {
        let m = BnnModel::random(32, &[64, 32, 1], 5);
        let x = PackedBits::from_u32(0xDEADBEEF);
        let traces = forward_trace(&m, &x);
        assert_eq!(traces.len(), 3);
        assert_eq!(traces[0].signs.len(), 64);
        assert_eq!(traces[1].signs.len(), 32);
        assert_eq!(traces[2].signs.len(), 1);
        assert_eq!(forward(&m, &x), traces[2].signs);
    }

    #[test]
    fn spec_mismatch_panics() {
        let m = BnnModel::random(32, &[16], 0);
        let x = PackedBits::zeros(64);
        assert!(std::panic::catch_unwind(|| forward(&m, &x)).is_err());
        let _ = BnnSpec::new(32, &[16]).unwrap(); // silence unused import
    }
}
