//! Loading the JAX-trained weights artifact (`artifacts/weights.json`).
//!
//! The JSON schema is produced by `python/compile/aot.py` (format
//! `n2net-weights-v1`) and carries: the `BnnSpec`, per-layer packed
//! weight rows, the synthetic-DDoS distribution parameters (so Rust
//! trace generation reproduces the training distribution), and training
//! metrics for reporting. Parsed with the in-crate JSON substrate
//! ([`crate::util::json`]).

use std::path::Path;

use super::bitpack::PackedBits;
use super::model::{BnnLayer, BnnModel, BnnSpec};
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// One layer entry of the weights document.
#[derive(Debug, Clone)]
pub struct LayerDoc {
    pub neurons: usize,
    pub in_bits: usize,
    pub threshold: u32,
    pub weights_packed: Vec<Vec<u32>>,
}

/// Subnet of the synthetic DDoS distribution.
#[derive(Debug, Clone, Copy)]
pub struct SubnetDoc {
    pub prefix: u32,
    pub prefix_len: u8,
}

impl SubnetDoc {
    /// Does `ip` fall inside this CIDR block?
    pub fn contains(&self, ip: u32) -> bool {
        if self.prefix_len == 0 {
            return true;
        }
        let mask = u32::MAX << (32 - self.prefix_len as u32);
        (ip & mask) == self.prefix
    }
}

/// DDoS distribution parameters (mirrors `python/compile/dataset.py`).
#[derive(Debug, Clone)]
pub struct DdosDoc {
    pub subnets: Vec<SubnetDoc>,
    pub attack_fraction: f64,
    pub seed: u64,
}

impl DdosDoc {
    /// Ground-truth label of an IP: 1 = attacker (blacklisted).
    pub fn label(&self, ip: u32) -> u32 {
        self.subnets.iter().any(|s| s.contains(ip)) as u32
    }
}

/// Training metrics recorded by `train.py`.
#[derive(Debug, Clone)]
pub struct MetricsDoc {
    pub train_accuracy_packed: f64,
    pub test_accuracy_packed: f64,
    pub final_loss: f64,
    pub loss_curve: Vec<f64>,
    pub steps: usize,
}

/// The full `weights.json` document.
#[derive(Debug, Clone)]
pub struct WeightsDoc {
    pub in_bits: usize,
    pub layer_sizes: Vec<usize>,
    pub layers: Vec<LayerDoc>,
    pub ddos: DdosDoc,
    pub metrics: MetricsDoc,
}

impl WeightsDoc {
    /// Parse + semantic checks.
    pub fn from_path(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts`?): {e}",
                path.as_ref().display()
            ))
        })?;
        Self::from_json(&text)
    }

    /// Parse from a JSON string.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        if v.req_str("format")? != "n2net-weights-v1" {
            return Err(Error::Artifact(format!(
                "unsupported weights format {:?}",
                v.req_str("format")?
            )));
        }
        let spec = v.req("spec")?;
        let in_bits = spec.req_usize("in_bits")?;
        let layer_sizes: Vec<usize> = spec
            .req_array("layer_sizes")?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| Error::Artifact("bad layer size".into())))
            .collect::<Result<_>>()?;

        let layers = v
            .req_array("layers")?
            .iter()
            .map(|l| {
                let rows = l
                    .req_array("weights_packed")?
                    .iter()
                    .map(|row| {
                        row.as_array()
                            .ok_or_else(|| Error::Artifact("weight row not array".into()))?
                            .iter()
                            .map(|x| {
                                x.as_u32().ok_or_else(|| {
                                    Error::Artifact("weight word not u32".into())
                                })
                            })
                            .collect::<Result<Vec<u32>>>()
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(LayerDoc {
                    neurons: l.req_usize("neurons")?,
                    in_bits: l.req_usize("in_bits")?,
                    threshold: l.req_u64("threshold")? as u32,
                    weights_packed: rows,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let d = v.req("ddos")?;
        let subnets = d
            .req_array("subnets")?
            .iter()
            .map(|s| {
                Ok(SubnetDoc {
                    prefix: s
                        .req_u64("prefix")?
                        .try_into()
                        .map_err(|_| Error::Artifact("prefix overflow".into()))?,
                    prefix_len: s.req_u64("prefix_len")? as u8,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let ddos = DdosDoc {
            subnets,
            attack_fraction: d.req_f64("attack_fraction")?,
            seed: d.req_u64("seed")?,
        };

        let m = v.req("metrics")?;
        let metrics = MetricsDoc {
            train_accuracy_packed: m.req_f64("train_accuracy_packed")?,
            test_accuracy_packed: m.req_f64("test_accuracy_packed")?,
            final_loss: m.req_f64("final_loss")?,
            loss_curve: m
                .get("loss_curve")
                .and_then(Value::as_array)
                .map(|a| a.iter().filter_map(Value::as_f64).collect())
                .unwrap_or_default(),
            steps: m.req_usize("steps")?,
        };

        Ok(WeightsDoc { in_bits, layer_sizes, layers, ddos, metrics })
    }

    /// Materialize the BNN model, validating every invariant.
    pub fn to_model(&self) -> Result<BnnModel> {
        let spec = BnnSpec::new(self.in_bits, &self.layer_sizes)?;
        let mut layers = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            if l.neurons != l.weights_packed.len() {
                return Err(Error::Artifact(format!(
                    "layer {i}: neurons={} but {} weight rows",
                    l.neurons,
                    l.weights_packed.len()
                )));
            }
            let expect_thresh = (l.in_bits as u32).div_ceil(2);
            if l.threshold != expect_thresh {
                return Err(Error::Artifact(format!(
                    "layer {i}: threshold {} != ceil(in_bits/2) = {expect_thresh}",
                    l.threshold
                )));
            }
            let rows = l
                .weights_packed
                .iter()
                .map(|row| PackedBits::from_words(row.clone(), l.in_bits))
                .collect();
            layers.push(BnnLayer::new(l.in_bits, rows)?);
        }
        BnnModel::new(spec, layers)
    }
}

/// Convenience: load + materialize in one call.
pub fn load_weights(path: impl AsRef<Path>) -> Result<(BnnModel, WeightsDoc)> {
    let doc = WeightsDoc::from_path(path)?;
    let model = doc.to_model()?;
    Ok((model, doc))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        let rows: Vec<String> = (0..16).map(|i| format!("[{i}]")).collect();
        format!(
            r#"{{
            "format": "n2net-weights-v1",
            "spec": {{"in_bits": 32, "layer_sizes": [16, 1]}},
            "layers": [
                {{"neurons": 16, "in_bits": 32, "threshold": 16,
                  "weights_packed": [{}]}},
                {{"neurons": 1, "in_bits": 16, "threshold": 8,
                  "weights_packed": [[43981]]}}
            ],
            "ddos": {{"subnets": [{{"prefix": 3232235520, "prefix_len": 16}}],
                      "attack_fraction": 0.5, "seed": 1}},
            "metrics": {{"train_accuracy_packed": 0.9, "test_accuracy_packed": 0.88,
                         "final_loss": 0.3, "loss_curve": [], "steps": 10}}
        }}"#,
            rows.join(",")
        )
    }

    #[test]
    fn load_roundtrip() {
        let doc = WeightsDoc::from_json(&sample_json()).unwrap();
        let model = doc.to_model().unwrap();
        assert_eq!(model.spec.layer_sizes, vec![16, 1]);
        assert_eq!(model.layers[1].neurons[0].words()[0], 0xABCD);
        assert_eq!(doc.ddos.subnets.len(), 1);
        assert!(doc.ddos.subnets[0].contains(0xC0A80001)); // 192.168.0.1
        assert!(!doc.ddos.subnets[0].contains(0xC0A90001));
        assert_eq!(doc.ddos.label(0xC0A80001), 1);
    }

    #[test]
    fn bad_threshold_rejected() {
        let bad = sample_json().replace("\"threshold\": 16", "\"threshold\": 5");
        let doc = WeightsDoc::from_json(&bad).unwrap();
        assert!(doc.to_model().is_err());
    }

    #[test]
    fn bad_format_rejected() {
        let bad = sample_json().replace("n2net-weights-v1", "v999");
        assert!(WeightsDoc::from_json(&bad).is_err());
    }

    #[test]
    fn missing_file_is_artifact_error() {
        match load_weights("/nonexistent/weights.json") {
            Err(Error::Artifact(msg)) => assert!(msg.contains("make artifacts")),
            other => panic!("expected Artifact error, got {other:?}"),
        }
    }

    #[test]
    fn real_artifact_loads_if_present() {
        // Exercised fully when `make artifacts` has run; skip otherwise.
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../artifacts/weights.json");
        if p.exists() {
            let (model, doc) = load_weights(&p).unwrap();
            assert_eq!(model.spec.in_bits, 32);
            assert!(doc.metrics.test_accuracy_packed > 0.5);
        }
    }
}
