//! Bit-packed BNN substrate.
//!
//! Shared conventions with the Python side (`python/compile/kernels/ref.py`):
//! logical bit *i* of a vector lives in word `i / 32` at position `i % 32`
//! (little-endian); bit 1 encodes +1, bit 0 encodes −1; a neuron fires
//! (`+1`) iff `popcount(XNOR(x, w)) >= ceil(n/2)`.
//!
//! [`forward`] is the *trusted* reference implementation (it uses the CPU
//! popcount intrinsic); the RMT pipeline ([`crate::rmt`]) and the PJRT
//! oracle ([`crate::runtime`]) are both checked bit-for-bit against it.

pub mod bitpack;
pub mod forward;
pub mod io;
pub mod model;

pub use bitpack::PackedBits;
pub use forward::{forward, forward_trace, layer_forward, LayerTrace};
pub use io::{load_weights, WeightsDoc};
pub use model::{BnnLayer, BnnModel, BnnSpec, MAX_BITS, MIN_BITS};
