//! Little-endian bit-packed vectors over `u32` words.
//!
//! The packing convention is load-bearing: it must match both the Python
//! oracle (`kernels/ref.py`) and the PHV container layout the compiler
//! emits (`crate::compiler::layout`), so that the same `u32` words flow
//! through all three implementations unchanged.

use std::fmt;

/// Word width used throughout (PHV's widest container is also 32 bits).
pub const WORD: usize = 32;

/// Number of `u32` words needed for `n_bits` packed bits.
#[inline]
pub const fn n_words(n_bits: usize) -> usize {
    n_bits.div_ceil(WORD)
}

/// Validity mask for the last word (all-ones when `n_bits % 32 == 0`).
#[inline]
pub const fn tail_mask(n_bits: usize) -> u32 {
    let rem = n_bits % WORD;
    if rem == 0 {
        u32::MAX
    } else {
        (1u32 << rem) - 1
    }
}

/// A bit-vector of fixed logical length, packed little-endian into u32s.
///
/// Invariant: bits beyond `n_bits` in the last word are always zero.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct PackedBits {
    words: Vec<u32>,
    n_bits: usize,
}

impl PackedBits {
    /// All-zero (all −1) vector of `n_bits`.
    pub fn zeros(n_bits: usize) -> Self {
        Self { words: vec![0; n_words(n_bits)], n_bits }
    }

    /// From raw words; masks the tail so the invariant holds.
    pub fn from_words(mut words: Vec<u32>, n_bits: usize) -> Self {
        words.resize(n_words(n_bits), 0);
        if let Some(last) = words.last_mut() {
            *last &= tail_mask(n_bits);
        }
        Self { words, n_bits }
    }

    /// From a slice of logical bits (`0`/`1`), bit 0 first.
    pub fn from_bits(bits: &[u8]) -> Self {
        let mut v = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b != 0 {
                v.set(i, true);
            }
        }
        v
    }

    /// A 32-bit value as a 32-bit packed vector (e.g. an IPv4 address).
    pub fn from_u32(value: u32) -> Self {
        Self { words: vec![value], n_bits: 32 }
    }

    /// Uniformly random vector (deterministic per seed).
    pub fn random(n_bits: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let mut words: Vec<u32> = (0..n_words(n_bits)).map(|_| rng.next_u32()).collect();
        if let Some(last) = words.last_mut() {
            *last &= tail_mask(n_bits);
        }
        Self { words, n_bits }
    }

    /// Logical length in bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.n_bits
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n_bits == 0
    }

    /// Backing words (tail already masked).
    #[inline]
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// Bit `i` as bool.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.n_bits, "bit index {i} out of range {}", self.n_bits);
        (self.words[i / WORD] >> (i % WORD)) & 1 == 1
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.n_bits, "bit index {i} out of range {}", self.n_bits);
        let (w, b) = (i / WORD, i % WORD);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Logical bits as a `Vec<u8>` of `0`/`1`.
    pub fn to_bits(&self) -> Vec<u8> {
        (0..self.n_bits).map(|i| self.get(i) as u8).collect()
    }

    /// XNOR against another vector of the same length (tail re-masked).
    pub fn xnor(&self, other: &Self) -> Self {
        assert_eq!(self.n_bits, other.n_bits, "xnor length mismatch");
        let words: Vec<u32> = self
            .words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| !(a ^ b))
            .collect();
        Self::from_words(words, self.n_bits)
    }

    /// Number of set bits.
    #[inline]
    pub fn popcount(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Number of positions where the two vectors agree (the XNOR-popcount
    /// pre-activation of a binary neuron).
    #[inline]
    pub fn agreement(&self, other: &Self) -> u32 {
        debug_assert_eq!(self.n_bits, other.n_bits);
        let full: u32 = self
            .words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (!(a ^ b)).count_ones())
            .sum();
        // !(a^b) sets the padding bits of the tail word; subtract them.
        full - (n_words(self.n_bits) * WORD - self.n_bits) as u32
    }

    /// Concatenate: `self` occupies the low bits, `other` follows.
    pub fn concat(&self, other: &Self) -> Self {
        let mut out = Self::zeros(self.n_bits + other.n_bits);
        for i in 0..self.n_bits {
            out.set(i, self.get(i));
        }
        for i in 0..other.n_bits {
            out.set(self.n_bits + i, other.get(i));
        }
        out
    }
}

impl fmt::Debug for PackedBits {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PackedBits[{}]{{", self.n_bits)?;
        for (i, w) in self.words.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{w:08x}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn n_words_and_masks() {
        assert_eq!(n_words(16), 1);
        assert_eq!(n_words(32), 1);
        assert_eq!(n_words(33), 2);
        assert_eq!(n_words(2048), 64);
        assert_eq!(tail_mask(16), 0xFFFF);
        assert_eq!(tail_mask(32), u32::MAX);
        assert_eq!(tail_mask(33), 1);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut v = PackedBits::zeros(100);
        v.set(0, true);
        v.set(31, true);
        v.set(32, true);
        v.set(99, true);
        assert!(v.get(0) && v.get(31) && v.get(32) && v.get(99));
        assert!(!v.get(1) && !v.get(98));
        assert_eq!(v.popcount(), 4);
    }

    #[test]
    fn from_bits_matches_from_words() {
        let bits: Vec<u8> = (0..48).map(|i| (i % 3 == 0) as u8).collect();
        let a = PackedBits::from_bits(&bits);
        assert_eq!(a.to_bits(), bits);
    }

    #[test]
    fn tail_invariant_enforced() {
        let v = PackedBits::from_words(vec![u32::MAX], 16);
        assert_eq!(v.words()[0], 0xFFFF);
        assert_eq!(v.popcount(), 16);
    }

    #[test]
    fn xnor_agreement_identity() {
        let mut rng = Rng::seed_from_u64(7);
        for n in [16usize, 32, 48, 129, 2048] {
            let a = PackedBits::random(n, &mut rng);
            let b = PackedBits::random(n, &mut rng);
            // agreement == popcount of tail-masked xnor
            assert_eq!(a.agreement(&b), a.xnor(&b).popcount(), "n={n}");
            // self-agreement is n
            assert_eq!(a.agreement(&a), n as u32);
        }
    }

    #[test]
    fn concat_layout() {
        let a = PackedBits::from_bits(&[1, 0, 1]);
        let b = PackedBits::from_bits(&[1, 1]);
        let c = a.concat(&b);
        assert_eq!(c.to_bits(), vec![1, 0, 1, 1, 1]);
    }
}
