//! BNN architecture description and packed weights.
//!
//! Mirrors `python/compile/model.py::BnnSpec` — the validation rules are
//! the paper's architectural constraints: every *activation* width (input
//! width and each hidden layer's size) must be a power of two in
//! `[16, 2048]`, because the PHV holds at most 2048 activation bits
//! (512 B / 2 after the duplication step) and the POPCNT tree assumes
//! power-of-two widths (Table 1's rows).

use super::bitpack::{n_words, tail_mask, PackedBits};
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Smallest activation width in Table 1.
pub const MIN_BITS: usize = 16;
/// Largest activation width in Table 1 (half the 512 B PHV).
pub const MAX_BITS: usize = 2048;

/// Architecture of a fully-connected BNN.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BnnSpec {
    /// Input activation vector width in bits.
    pub in_bits: usize,
    /// Neurons per layer, in order.
    pub layer_sizes: Vec<usize>,
}

impl BnnSpec {
    /// Validated constructor.
    pub fn new(in_bits: usize, layer_sizes: &[usize]) -> Result<Self> {
        let spec = Self { in_bits, layer_sizes: layer_sizes.to_vec() };
        spec.validate()?;
        Ok(spec)
    }

    /// Check the paper's architectural constraints.
    pub fn validate(&self) -> Result<()> {
        if self.layer_sizes.is_empty() {
            return Err(Error::InvalidModel("need at least one layer".into()));
        }
        let mut widths = vec![self.in_bits];
        widths.extend(&self.layer_sizes[..self.layer_sizes.len() - 1]);
        for &w in &widths {
            if !(MIN_BITS..=MAX_BITS).contains(&w) || !w.is_power_of_two() {
                return Err(Error::InvalidModel(format!(
                    "activation width {w} must be a power of two in \
                     [{MIN_BITS}, {MAX_BITS}] (paper Table 1)"
                )));
            }
        }
        let last = *self.layer_sizes.last().unwrap();
        if last == 0 {
            return Err(Error::InvalidModel("output layer needs >= 1 neuron".into()));
        }
        Ok(())
    }

    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        self.layer_sizes.len()
    }

    /// Activation width feeding layer `i`.
    pub fn layer_in_bits(&self, i: usize) -> usize {
        if i == 0 {
            self.in_bits
        } else {
            self.layer_sizes[i - 1]
        }
    }

    /// `(neurons, in_bits)` per layer.
    pub fn layer_shapes(&self) -> Vec<(usize, usize)> {
        (0..self.n_layers())
            .map(|i| (self.layer_sizes[i], self.layer_in_bits(i)))
            .collect()
    }

    /// Total packed weight storage in bits (the element-SRAM demand).
    pub fn weight_bits_total(&self) -> usize {
        self.layer_shapes().iter().map(|(m, n)| m * n).sum()
    }
}

/// One layer's packed weights.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BnnLayer {
    /// Activation width (bits) this layer consumes.
    pub in_bits: usize,
    /// One packed weight row per neuron, each of `in_bits` logical bits.
    pub neurons: Vec<PackedBits>,
    /// SIGN threshold: `ceil(in_bits / 2)` (paper: "bigger or equal to
    /// half the length of the activations vector").
    pub threshold: u32,
}

impl BnnLayer {
    /// Build from packed rows; validates row widths.
    pub fn new(in_bits: usize, neurons: Vec<PackedBits>) -> Result<Self> {
        for (j, r) in neurons.iter().enumerate() {
            if r.len() != in_bits {
                return Err(Error::InvalidModel(format!(
                    "layer expects {in_bits}-bit rows, neuron {j} has {}",
                    r.len()
                )));
            }
        }
        Ok(Self { in_bits, neurons, threshold: (in_bits as u32).div_ceil(2) })
    }

    /// Number of neurons (output bits) in this layer.
    pub fn n_neurons(&self) -> usize {
        self.neurons.len()
    }
}

/// A complete BNN: spec + per-layer packed weights.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BnnModel {
    pub spec: BnnSpec,
    pub layers: Vec<BnnLayer>,
}

impl BnnModel {
    /// Assemble and cross-validate spec against weights.
    pub fn new(spec: BnnSpec, layers: Vec<BnnLayer>) -> Result<Self> {
        spec.validate()?;
        if layers.len() != spec.n_layers() {
            return Err(Error::InvalidModel(format!(
                "spec has {} layers, weights have {}",
                spec.n_layers(),
                layers.len()
            )));
        }
        for (i, l) in layers.iter().enumerate() {
            if l.in_bits != spec.layer_in_bits(i) {
                return Err(Error::InvalidModel(format!(
                    "layer {i}: spec in_bits {} != weights in_bits {}",
                    spec.layer_in_bits(i),
                    l.in_bits
                )));
            }
            if l.n_neurons() != spec.layer_sizes[i] {
                return Err(Error::InvalidModel(format!(
                    "layer {i}: spec neurons {} != weight rows {}",
                    spec.layer_sizes[i],
                    l.n_neurons()
                )));
            }
        }
        Ok(Self { spec, layers })
    }

    /// Deterministic random model (tests, benchmarks).
    pub fn random(in_bits: usize, layer_sizes: &[usize], seed: u64) -> Self {
        let spec = BnnSpec::new(in_bits, layer_sizes).expect("invalid random spec");
        let mut rng = Rng::seed_from_u64(seed);
        let layers = spec
            .layer_shapes()
            .into_iter()
            .map(|(m, n)| {
                let rows = (0..m).map(|_| PackedBits::random(n, &mut rng)).collect();
                BnnLayer::new(n, rows).unwrap()
            })
            .collect();
        Self { spec, layers }
    }

    /// Packed words of every weight row of layer `i`, flattened row-major
    /// (one `n_words(in_bits)` stride per neuron) — what the compiler bakes
    /// into element action immediates.
    pub fn layer_weight_words(&self, i: usize) -> Vec<u32> {
        let l = &self.layers[i];
        let stride = n_words(l.in_bits);
        let mut out = Vec::with_capacity(l.n_neurons() * stride);
        for row in &l.neurons {
            out.extend_from_slice(row.words());
            debug_assert_eq!(row.words().len(), stride);
            debug_assert_eq!(row.words().last().map_or(0, |w| w & !tail_mask(l.in_bits)), 0);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validation() {
        assert!(BnnSpec::new(32, &[64, 32, 1]).is_ok());
        assert!(BnnSpec::new(32, &[2048]).is_ok());
        // 48 is not a power of two
        assert!(BnnSpec::new(48, &[16]).is_err());
        // 8 below MIN_BITS
        assert!(BnnSpec::new(8, &[16]).is_err());
        // 4096 above MAX_BITS
        assert!(BnnSpec::new(4096, &[16]).is_err());
        // hidden layer size 48 becomes an invalid activation width
        assert!(BnnSpec::new(32, &[48, 16]).is_err());
        // but an odd *final* layer is fine (classifier head)
        assert!(BnnSpec::new(32, &[64, 3]).is_ok());
        assert!(BnnSpec::new(32, &[]).is_err());
    }

    #[test]
    fn shapes_and_totals() {
        let s = BnnSpec::new(32, &[64, 32, 1]).unwrap();
        assert_eq!(s.layer_shapes(), vec![(64, 32), (32, 64), (1, 32)]);
        assert_eq!(s.weight_bits_total(), 64 * 32 + 32 * 64 + 32);
        assert_eq!(s.layer_in_bits(0), 32);
        assert_eq!(s.layer_in_bits(2), 32);
    }

    #[test]
    fn random_model_consistent() {
        let m = BnnModel::random(64, &[32, 16], 1);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].n_neurons(), 32);
        assert_eq!(m.layers[0].threshold, 32);
        assert_eq!(m.layer_weight_words(0).len(), 32 * 2);
        // Determinism
        let m2 = BnnModel::random(64, &[32, 16], 1);
        assert_eq!(m, m2);
    }

    #[test]
    fn model_weight_mismatch_rejected() {
        let spec = BnnSpec::new(32, &[16]).unwrap();
        let bad_layer =
            BnnLayer::new(32, vec![PackedBits::zeros(32); 8]).unwrap();
        assert!(BnnModel::new(spec, vec![bad_layer]).is_err());
    }
}
