//! `n2net::deploy` — the canonical public API: a switch chip as a
//! *deployment target* for BNN models (DESIGN.md §11).
//!
//! The paper closes by calling N2Net "an interesting building block for
//! future end-to-end networked systems"; this module is that building
//! block's front door. One builder call covers every serving scenario:
//!
//! * **single model** — `Deployment::builder().model("ddos", m).build()?`
//! * **multi-model registry** — several `.model(..)` calls; each model
//!   gets its own compiled program and named publication slot;
//! * **keyed-table multi-model** — `.keyed(id_offset)` compiles ALL
//!   registered models into ONE pipeline program via
//!   [`Compiler::compile_multi`], a packet header field selecting the
//!   weights per packet (the Brain-on-Switch / model-switching shape);
//! * **baseline comparison** — [`Deployment::session_with`] opens
//!   sessions with different [`BackendKind`]s over the same deployment;
//! * **runtime hot-swap** — [`Deployment::swap_model`] recompiles off
//!   the hot path and atomically publishes the new artifact to every
//!   session and engine worker (RCU-style, see [`swap`]), with a
//!   monotone version counter surfaced in
//!   [`EngineReport::model_version`](crate::coordinator::EngineReport).
//!
//! Input extraction is typed ([`FieldExtractor`]) instead of raw byte
//! offsets, and classification goes through [`Session`] /
//! [`KeyedSession`] handles (single-threaded, one per worker), the
//! multi-worker [`Engine`](crate::coordinator::Engine) via
//! [`Deployment::engine`], or the sharded flow-affinity tier via
//! [`Deployment::sharded_engine`] (DESIGN.md §12) — N queue-fed
//! backends behind an RSS-style dispatcher, with a streaming ingest
//! handle ([`crate::coordinator::ShardedStream`]) and explicit
//! backpressure/drop accounting.
//!
//! Below this sits the low-level layer — [`crate::backend::make_backend`],
//! [`Engine::new`](crate::coordinator::Engine::new), raw
//! [`Compiler`] driving — which stays public for tests and
//! simulator-internals work but is no longer what apps, benches, or the
//! CLI wire by hand.

pub mod extract;
pub mod session;
pub mod swap;

pub use extract::FieldExtractor;
pub use session::{KeyedSession, Session};
pub use swap::{ModelArtifact, ModelCounters, ModelSlot, SwapCell};

pub(crate) use session::backend_for_artifact;

use std::sync::{Arc, Mutex};

use crate::backend::BackendKind;
use crate::baseline::LutClassifier;
use crate::bnn::BnnModel;
use crate::compiler::{
    CompiledModel, Compiler, CompilerOptions, MultiModelOptions,
};
use crate::coordinator::{
    BatchPolicy, Engine, EngineConfig, EngineReport, RouterPolicy, ShardConfig,
    ShardedEngine, ShardedReport,
};
use crate::error::{Error, Result};
use crate::rmt::ChipConfig;

/// One registered model: its identity, current source weights, and (in
/// isolated mode) its own publication slot.
struct DeployEntry {
    name: String,
    /// Keyed-table match key (also assigned in isolated mode for
    /// stable identity; index-based unless given explicitly).
    id: u32,
    /// Current source model — what [`Deployment::swap_model`] validates
    /// against and what keyed recompiles re-read.
    model: Mutex<Arc<BnnModel>>,
    /// Per-model publication slot (isolated mode; `None` when keyed).
    slot: Option<Arc<ModelSlot>>,
    counters: Arc<ModelCounters>,
}

/// The shared keyed-table program of a keyed deployment.
struct KeyedProgram {
    slot: Arc<ModelSlot>,
    id_offset: usize,
}

/// Per-model serving stats snapshot (see [`Deployment::stats`]).
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub name: String,
    /// Packets routed to this model through sessions.
    pub packets: u64,
    /// Malformed packets attributed to this model.
    pub parse_errors: u64,
    /// Hot-swaps published for this model.
    pub swaps: u64,
    /// Current published version of the model's program.
    pub version: u64,
}

/// A built deployment: compiled model registry + serving configuration.
/// Shared freely across threads (`Arc<Deployment>`); open one
/// [`Session`] per worker thread, or drive the multi-worker engine via
/// [`Deployment::engine`] / [`Deployment::serve_trace`].
pub struct Deployment {
    chip: ChipConfig,
    /// Compiler options with the extractor's encoding substituted in —
    /// reused verbatim by hot-swap recompiles.
    opts: CompilerOptions,
    backend: BackendKind,
    extractor: FieldExtractor,
    entries: Vec<DeployEntry>,
    keyed: Option<KeyedProgram>,
    lut: Option<Arc<LutClassifier>>,
    n_workers: usize,
    router: RouterPolicy,
    batch: BatchPolicy,
    /// Serializes swaps so concurrent `swap_model` calls cannot publish
    /// an artifact that disagrees with the registry.
    swap_gate: Mutex<()>,
}

impl Deployment {
    /// Start building a deployment (see [`DeploymentBuilder`]).
    pub fn builder() -> DeploymentBuilder {
        DeploymentBuilder::default()
    }

    fn entry(&self, name: &str) -> Result<&DeployEntry> {
        self.entries.iter().find(|e| e.name == name).ok_or_else(|| {
            let known: Vec<&str> =
                self.entries.iter().map(|e| e.name.as_str()).collect();
            Error::Config(format!(
                "no model {name:?} in this deployment (registered: {known:?})"
            ))
        })
    }

    /// Names of the registered models, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Whether this deployment serves all models from one keyed-table
    /// program.
    pub fn is_keyed(&self) -> bool {
        self.keyed.is_some()
    }

    /// The backend kind sessions and engines default to.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend
    }

    /// The typed input extractor this deployment compiles for.
    pub fn extractor(&self) -> FieldExtractor {
        self.extractor
    }

    fn slot_for(&self, entry: &DeployEntry) -> Arc<ModelSlot> {
        match (&entry.slot, &self.keyed) {
            (Some(slot), _) => Arc::clone(slot),
            (None, Some(k)) => Arc::clone(&k.slot),
            (None, None) => unreachable!("entry without slot in isolated mode"),
        }
    }

    /// The currently published compiled program for `name` (the shared
    /// program in keyed mode) — resource reports, schedule listings.
    pub fn compiled(&self, name: &str) -> Result<Arc<CompiledModel>> {
        let entry = self.entry(name)?;
        Ok(Arc::clone(&self.slot_for(entry).load().0.compiled))
    }

    /// Current published version of `name`'s program (monotone; starts
    /// at 1, bumped by every [`Deployment::swap_model`]).
    pub fn version(&self, name: &str) -> Result<u64> {
        let entry = self.entry(name)?;
        Ok(self.slot_for(entry).version())
    }

    /// Per-model serving stats snapshot.
    pub fn stats(&self, name: &str) -> Result<ModelStats> {
        let entry = self.entry(name)?;
        Ok(ModelStats {
            name: entry.name.clone(),
            packets: entry.counters.packets.get(),
            parse_errors: entry.counters.parse_errors.get(),
            swaps: entry.counters.swaps.get(),
            version: self.slot_for(entry).version(),
        })
    }

    /// Register every model's serving counters and published version
    /// into a [`crate::obs::MetricsRegistry`] under
    /// `{prefix}.model.{name}.*` — the registry-side equivalent of
    /// polling [`Deployment::stats`] per model, sharing the same
    /// [`ModelCounters`] atomics the sessions bump.
    pub fn register_metrics(&self, reg: &crate::obs::MetricsRegistry, prefix: &str) {
        for entry in &self.entries {
            let base = format!("{prefix}.model.{}", entry.name);
            let c = Arc::clone(&entry.counters);
            reg.counter_fn(&format!("{base}.packets"), move || c.packets.get());
            let c = Arc::clone(&entry.counters);
            reg.counter_fn(&format!("{base}.parse_errors"), move || {
                c.parse_errors.get()
            });
            let c = Arc::clone(&entry.counters);
            reg.counter_fn(&format!("{base}.swaps"), move || c.swaps.get());
            let slot = self.slot_for(entry);
            reg.gauge_fn(&format!("{base}.version"), move || slot.version());
        }
    }

    /// Open a classify session for `name` on the deployment's default
    /// backend.
    pub fn session(&self, name: &str) -> Result<Session> {
        self.session_with(name, self.backend)
    }

    /// Open a classify session for `name` on an explicit backend — the
    /// baseline-comparison scenario (e.g. a `reference` session A/B'd
    /// against the `batched` default over the same deployment).
    pub fn session_with(&self, name: &str, kind: BackendKind) -> Result<Session> {
        if self.is_keyed() {
            return Err(Error::Config(
                "keyed deployment serves all models from one program: \
                 use keyed_session()"
                    .into(),
            ));
        }
        let entry = self.entry(name)?;
        Session::open(
            self.slot_for(entry),
            kind,
            self.lut.clone(),
            Some(Arc::clone(&entry.counters)),
        )
    }

    /// Open the mixed-model session of a keyed deployment.
    pub fn keyed_session(&self) -> Result<KeyedSession> {
        self.keyed_session_with(self.backend)
    }

    /// Only backends that execute the keyed pipeline program can honor
    /// per-packet model ids; the reference forward replays ONE model
    /// and the LUT baseline consults one shared table, so a keyed
    /// deployment must reject both rather than silently serve the
    /// default classifier to every tenant.
    fn check_keyed_backend(kind: BackendKind) -> Result<()> {
        match kind {
            BackendKind::Reference => Err(Error::Config(
                "the reference backend replays a single model's forward pass \
                 and cannot honor per-packet model ids — use an isolated \
                 deployment (one session per model) for reference A/B checks"
                    .into(),
            )),
            BackendKind::Lut => Err(Error::Config(
                "the LUT baseline classifies against one shared table and \
                 cannot honor per-packet model ids — compare it on an \
                 isolated deployment instead"
                    .into(),
            )),
            BackendKind::Specialized => Err(Error::Config(
                "the specialized backend monomorphizes one model's weights \
                 into straight-line kernels and cannot honor per-packet \
                 model ids — use an isolated deployment, or \
                 scalar|batched for the keyed program"
                    .into(),
            )),
            _ => Ok(()),
        }
    }

    /// Same, with an explicit backend choice.
    pub fn keyed_session_with(&self, kind: BackendKind) -> Result<KeyedSession> {
        Self::check_keyed_backend(kind)?;
        let keyed = self.keyed.as_ref().ok_or_else(|| {
            Error::Config(
                "not a keyed deployment: enable with builder.keyed(id_offset)"
                    .into(),
            )
        })?;
        let by_id = self
            .entries
            .iter()
            .map(|e| (e.id, Arc::clone(&e.counters)))
            .collect();
        KeyedSession::open(
            Arc::clone(&keyed.slot),
            kind,
            self.lut.clone(),
            keyed.id_offset,
            by_id,
        )
    }

    fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            n_workers: self.n_workers,
            router: self.router,
            backend: self.backend,
            batch: self.batch,
        }
    }

    /// A multi-worker engine over `name`'s publication slot. Workers
    /// pick up hot-swaps at batch boundaries; the engine's report
    /// carries the serving version.
    pub fn engine(&self, name: &str) -> Result<Engine> {
        if self.is_keyed() {
            return Err(Error::Config(
                "keyed deployment serves all models from one program: \
                 use engine_keyed()"
                    .into(),
            ));
        }
        let entry = self.entry(name)?;
        Ok(Engine::from_slot(
            self.slot_for(entry),
            self.lut.clone(),
            self.engine_config(),
        ))
    }

    /// A multi-worker engine over the shared keyed-table program.
    pub fn engine_keyed(&self) -> Result<Engine> {
        Self::check_keyed_backend(self.backend)?;
        let keyed = self.keyed.as_ref().ok_or_else(|| {
            Error::Config(
                "not a keyed deployment: enable with builder.keyed(id_offset)"
                    .into(),
            )
        })?;
        Ok(Engine::from_slot(
            Arc::clone(&keyed.slot),
            self.lut.clone(),
            self.engine_config(),
        ))
    }

    fn shard_config(&self, n_shards: usize) -> ShardConfig {
        ShardConfig {
            n_shards: n_shards.max(1),
            backend: self.backend,
            batch: self.batch,
            ..ShardConfig::default()
        }
    }

    /// The sharded serving tier over `name`'s publication slot
    /// (DESIGN.md §12): an RSS-style dispatcher flow-hashes frames
    /// across `n_shards` queue-fed backends; call
    /// [`ShardedEngine::stream`] for the streaming ingest handle or
    /// [`ShardedEngine::process_trace`] for whole traces. Hot-swaps are
    /// picked up per shard at batch boundaries; the merged report
    /// surfaces any transient version skew.
    pub fn sharded_engine(&self, name: &str, n_shards: usize) -> Result<ShardedEngine> {
        if self.is_keyed() {
            return Err(Error::Config(
                "keyed deployment serves all models from one program: \
                 use sharded_engine_keyed()"
                    .into(),
            ));
        }
        let entry = self.entry(name)?;
        Ok(ShardedEngine::from_slot(
            self.slot_for(entry),
            self.lut.clone(),
            self.shard_config(n_shards),
        ))
    }

    /// The sharded serving tier over the shared keyed-table program.
    /// Every shard can serve every tenant (the keyed tables ride in the
    /// program, not in the shard), so flow affinity never constrains
    /// which models a shard hosts.
    pub fn sharded_engine_keyed(&self, n_shards: usize) -> Result<ShardedEngine> {
        Self::check_keyed_backend(self.backend)?;
        let keyed = self.keyed.as_ref().ok_or_else(|| {
            Error::Config(
                "not a keyed deployment: enable with builder.keyed(id_offset)"
                    .into(),
            )
        })?;
        Ok(ShardedEngine::from_slot(
            Arc::clone(&keyed.slot),
            self.lut.clone(),
            self.shard_config(n_shards),
        ))
    }

    /// The sharded tier as a shared handle — what the live control
    /// plane wants: the same `Arc<ShardedEngine>` goes to the serving
    /// side (`engine.live_stream()`) and to the controller
    /// ([`Controller::with_tier`](crate::controlplane::Controller::with_tier)
    /// + [`controlplane::spawn_live`](crate::controlplane::spawn_live)),
    /// so reshard / backend-switch / overflow-flip actions reach the
    /// running dispatcher and workers through the engine's shared
    /// reconfiguration cell (DESIGN.md §14).
    pub fn live_sharded_engine(
        &self,
        name: &str,
        n_shards: usize,
    ) -> Result<Arc<ShardedEngine>> {
        Ok(Arc::new(self.sharded_engine(name, n_shards)?))
    }

    /// Serve a whole trace through a fresh sharded engine.
    pub fn serve_trace_sharded(
        &self,
        name: &str,
        n_shards: usize,
        packets: &[Vec<u8>],
    ) -> Result<ShardedReport> {
        self.sharded_engine(name, n_shards)?.process_trace(packets)
    }

    /// Serve a whole trace through a fresh multi-worker engine.
    pub fn serve_trace(
        &self,
        name: &str,
        packets: &[Vec<u8>],
    ) -> Result<EngineReport> {
        self.engine(name)?.process_trace(packets)
    }

    /// Serve a mixed-model trace through the keyed program.
    pub fn serve_trace_keyed(&self, packets: &[Vec<u8>]) -> Result<EngineReport> {
        self.engine_keyed()?.process_trace(packets)
    }

    /// Runtime hot-swap: replace `name`'s weights with `new_model`
    /// (same architecture — the pipeline program shape is fixed at
    /// deploy time), recompiling **off the hot path** and atomically
    /// publishing the result to every open session and engine worker.
    /// In-flight batches finish on the old artifact; the next batch
    /// boundary serves the new one. Returns the new version. On error
    /// (e.g. a compile failure) the old model keeps serving untouched.
    pub fn swap_model(&self, name: &str, new_model: BnnModel) -> Result<u64> {
        let _gate = self.swap_gate.lock().expect("swap gate poisoned");
        let entry = self.entry(name)?;
        {
            let current = entry.model.lock().expect("model lock poisoned");
            if new_model.spec != current.spec {
                return Err(Error::InvalidModel(format!(
                    "hot-swap of {name:?} requires the deployed architecture \
                     ({}b -> {:?}); got {}b -> {:?} — redeploy for a new \
                     architecture",
                    current.spec.in_bits,
                    current.spec.layer_sizes,
                    new_model.spec.in_bits,
                    new_model.spec.layer_sizes,
                )));
            }
        }
        let new_model = Arc::new(new_model);
        let version = match (&entry.slot, &self.keyed) {
            (Some(slot), _) => {
                // Isolated mode: recompile this model's own program.
                let compiled = Arc::new(
                    Compiler::new(self.chip.clone(), self.opts.clone())
                        .compile(&new_model)?,
                );
                publish_verified(entry, slot, new_model, compiled)?
            }
            (None, Some(keyed)) => {
                // Keyed mode: recompile the whole shared program with the
                // swapped entry substituted; the registry is only updated
                // once the compile succeeds.
                let pairs: Vec<(u32, BnnModel)> = self
                    .entries
                    .iter()
                    .map(|e| {
                        let m = if e.name == name {
                            new_model.as_ref().clone()
                        } else {
                            e.model.lock().expect("model lock poisoned").as_ref().clone()
                        };
                        (e.id, m)
                    })
                    .collect();
                let compiled = Arc::new(
                    Compiler::new(self.chip.clone(), self.opts.clone())
                        .compile_multi(
                            &pairs,
                            MultiModelOptions { id_offset: keyed.id_offset },
                        )?,
                );
                // Verify the artifact BEFORE touching the registry, so
                // a refused publish leaves registry and slot in sync.
                let default_model = Arc::new(pairs[0].1.clone());
                let artifact = ModelArtifact::new(default_model, compiled)?;
                *entry.model.lock().expect("model lock poisoned") =
                    Arc::clone(&new_model);
                keyed.slot.publish(artifact)
            }
            (None, None) => unreachable!("entry without slot in isolated mode"),
        };
        entry.counters.swaps.inc();
        Ok(version)
    }
}

/// The last step of an isolated-mode hot-swap: build the artifact —
/// which runs the publish gate in [`ModelArtifact::new`]
/// (DESIGN.md §17) — and only then update the weight registry and
/// publish to the slot. A refused artifact therefore leaves BOTH the
/// serving slot and the registry exactly as they were (the
/// swap-atomicity contract). Factored out of [`Deployment::swap_model`]
/// so the gating tests can drive the real publish path with a
/// deliberately-illegal compiled program, which the honest compiler
/// never emits.
fn publish_verified(
    entry: &DeployEntry,
    slot: &ModelSlot,
    model: Arc<BnnModel>,
    compiled: Arc<CompiledModel>,
) -> Result<u64> {
    let artifact = ModelArtifact::new(Arc::clone(&model), compiled)?;
    *entry.model.lock().expect("model lock poisoned") = model;
    Ok(slot.publish(artifact))
}

/// A controller-safe swap capability for ONE registered model of a
/// deployment (DESIGN.md §13): the handle can publish weight swaps,
/// read the version, and read stats — nothing else — so the control
/// plane holds exactly the authority it needs over the serving tier.
/// It is `Clone + Send + Sync` and validated at creation; every swap
/// still goes through [`Deployment::swap_model`], so architecture
/// validation, off-hot-path recompilation, and atomic publication are
/// identical to a hand-driven swap.
#[derive(Clone)]
pub struct SwapHandle {
    deployment: Arc<Deployment>,
    model: String,
}

impl SwapHandle {
    /// Open a handle for `name`; fails fast on an unregistered model.
    pub fn new(deployment: &Arc<Deployment>, name: &str) -> Result<SwapHandle> {
        deployment.entry(name)?;
        Ok(SwapHandle {
            deployment: Arc::clone(deployment),
            model: name.to_string(),
        })
    }

    /// Name of the model this handle can swap.
    pub fn model_name(&self) -> &str {
        &self.model
    }

    /// Publish a weight swap (see [`Deployment::swap_model`]); returns
    /// the new version. A rejected swap (architecture mismatch, compile
    /// failure) publishes nothing and the live model keeps serving.
    pub fn swap(&self, new_model: BnnModel) -> Result<u64> {
        self.deployment.swap_model(&self.model, new_model)
    }

    /// Currently published version of the handled model.
    pub fn version(&self) -> Result<u64> {
        self.deployment.version(&self.model)
    }

    /// Serving stats snapshot of the handled model.
    pub fn stats(&self) -> Result<ModelStats> {
        self.deployment.stats(&self.model)
    }
}

/// Builder for a [`Deployment`]. Defaults: stock RMT chip, `src-ip`
/// extraction, `batched` backend, round-robin engine routing.
pub struct DeploymentBuilder {
    chip: ChipConfig,
    extractor: FieldExtractor,
    backend: BackendKind,
    opts: CompilerOptions,
    models: Vec<(String, Option<u32>, BnnModel)>,
    keyed: Option<usize>,
    lut: Option<LutClassifier>,
    n_workers: usize,
    router: RouterPolicy,
    batch: BatchPolicy,
}

impl Default for DeploymentBuilder {
    fn default() -> Self {
        let engine = EngineConfig::default();
        Self {
            chip: ChipConfig::rmt(),
            extractor: FieldExtractor::default(),
            backend: BackendKind::default(),
            opts: CompilerOptions::default(),
            models: Vec::new(),
            keyed: None,
            lut: None,
            n_workers: engine.n_workers,
            router: engine.router,
            batch: engine.batch,
        }
    }
}

impl DeploymentBuilder {
    /// Target chip (default: stock RMT; `ChipConfig::rmt_with_popcnt()`
    /// for the §3 native-POPCNT variant).
    pub fn chip(mut self, chip: ChipConfig) -> Self {
        self.chip = chip;
        self
    }

    /// Typed input extraction (default: [`FieldExtractor::SrcIp`]).
    pub fn extractor(mut self, extractor: FieldExtractor) -> Self {
        self.extractor = extractor;
        self
    }

    /// Default backend for sessions and engines (default: batched).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = kind;
        self
    }

    /// Register a model under `name` (keyed-table id auto-assigned from
    /// registration order).
    pub fn model(mut self, name: impl Into<String>, model: BnnModel) -> Self {
        self.models.push((name.into(), None, model));
        self
    }

    /// Register a model with an explicit keyed-table match id.
    pub fn model_with_id(
        mut self,
        name: impl Into<String>,
        id: u32,
        model: BnnModel,
    ) -> Self {
        self.models.push((name.into(), Some(id), model));
        self
    }

    /// Serve every registered model from ONE keyed-table pipeline
    /// program ([`Compiler::compile_multi`]); the 32-bit little-endian
    /// model id at `id_offset` in the packet selects the weights, the
    /// first registered model being the table-miss default.
    pub fn keyed(mut self, id_offset: usize) -> Self {
        self.keyed = Some(id_offset);
        self
    }

    /// Attach the exact-match LUT baseline (enables
    /// [`BackendKind::Lut`] sessions/engines for apples-to-apples
    /// comparisons).
    pub fn lut(mut self, lut: LutClassifier) -> Self {
        self.lut = Some(lut);
        self
    }

    /// Engine worker count (default: host parallelism, capped at 8).
    pub fn workers(mut self, n: usize) -> Self {
        self.n_workers = n.max(1);
        self
    }

    /// Engine packet routing policy.
    pub fn router(mut self, router: RouterPolicy) -> Self {
        self.router = router;
        self
    }

    /// Engine batch formation policy.
    pub fn batch(mut self, batch: BatchPolicy) -> Self {
        self.batch = batch;
        self
    }

    /// Escape hatch for compiler knobs (recirculation, immediates,
    /// parallelism caps). The `input` field is overridden by the
    /// builder's [`extractor`](DeploymentBuilder::extractor).
    pub fn compiler_options(mut self, opts: CompilerOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Compile every registered model and assemble the deployment.
    pub fn build(self) -> Result<Deployment> {
        if self.models.is_empty() {
            return Err(Error::Config(
                "deployment needs at least one model: builder.model(name, model)"
                    .into(),
            ));
        }
        if self.backend == BackendKind::Lut && self.lut.is_none() {
            return Err(Error::Config(session::LUT_TABLE_HINT.into()));
        }
        if self.keyed.is_some() {
            Deployment::check_keyed_backend(self.backend)?;
        }
        let opts = CompilerOptions { input: self.extractor.encoding(), ..self.opts };

        // Resolve identities: unique names, unique ids (explicit ids
        // win; auto-assignment skips every explicit id — wherever it
        // was registered — so mixing model() and model_with_id()
        // cannot self-collide).
        let explicit: Vec<u32> = self.models.iter().filter_map(|(_, id, _)| *id).collect();
        let mut resolved: Vec<(String, u32, BnnModel)> = Vec::new();
        let mut next_auto = 0u32;
        for (name, id, model) in self.models {
            let id = match id {
                Some(id) => id,
                None => {
                    while explicit.contains(&next_auto)
                        || resolved.iter().any(|(_, k, _)| *k == next_auto)
                    {
                        next_auto += 1;
                    }
                    let auto = next_auto;
                    next_auto += 1;
                    auto
                }
            };
            if resolved.iter().any(|(n, _, _)| *n == name) {
                return Err(Error::Config(format!(
                    "duplicate model name {name:?} in deployment"
                )));
            }
            if resolved.iter().any(|(_, k, _)| *k == id) {
                return Err(Error::Config(format!(
                    "duplicate model id {id} in deployment"
                )));
            }
            resolved.push((name, id, model));
        }

        let mut entries = Vec::with_capacity(resolved.len());
        let keyed = match self.keyed {
            Some(id_offset) => {
                // One shared program over every model, weights selected
                // per packet by the keyed match stage.
                let pairs: Vec<(u32, BnnModel)> = resolved
                    .iter()
                    .map(|(_, id, m)| (*id, m.clone()))
                    .collect();
                let compiled = Arc::new(
                    Compiler::new(self.chip.clone(), opts.clone())
                        .compile_multi(&pairs, MultiModelOptions { id_offset })?,
                );
                let slot = Arc::new(ModelSlot::new(
                    "keyed-program",
                    ModelArtifact::new(Arc::new(pairs[0].1.clone()), compiled)?,
                ));
                for (name, id, model) in resolved {
                    entries.push(DeployEntry {
                        name,
                        id,
                        model: Mutex::new(Arc::new(model)),
                        slot: None,
                        counters: Arc::new(ModelCounters::default()),
                    });
                }
                Some(KeyedProgram { slot, id_offset })
            }
            None => {
                // Isolated mode: one program + publication slot each.
                for (name, id, model) in resolved {
                    let model = Arc::new(model);
                    let compiled = Arc::new(
                        Compiler::new(self.chip.clone(), opts.clone())
                            .compile(&model)?,
                    );
                    let slot = Arc::new(ModelSlot::new(
                        name.clone(),
                        ModelArtifact::new(Arc::clone(&model), compiled)?,
                    ));
                    entries.push(DeployEntry {
                        name,
                        id,
                        model: Mutex::new(model),
                        slot: Some(slot),
                        counters: Arc::new(ModelCounters::default()),
                    });
                }
                None
            }
        };

        Ok(Deployment {
            chip: self.chip,
            opts,
            backend: self.backend,
            extractor: self.extractor,
            entries,
            keyed,
            lut: self.lut.map(Arc::new),
            n_workers: self.n_workers,
            router: self.router,
            batch: self.batch,
            swap_gate: Mutex::new(()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{self, PackedBits};
    use crate::net::{TraceGenerator, TraceKind};

    fn deployment_for(model: &BnnModel, kind: BackendKind) -> Deployment {
        Deployment::builder()
            .extractor(FieldExtractor::SrcIp)
            .backend(kind)
            .model("m", model.clone())
            .build()
            .unwrap()
    }

    #[test]
    fn session_matches_reference_forward() {
        let model = BnnModel::random(32, &[16, 1], 41);
        let mut gen = TraceGenerator::new(5);
        let trace = gen.generate(&TraceKind::UniformIps, 64);
        for kind in [
            BackendKind::Scalar,
            BackendKind::Batched,
            BackendKind::Reference,
            BackendKind::Specialized,
        ] {
            let dep = deployment_for(&model, kind);
            let mut session = dep.session("m").unwrap();
            assert_eq!(session.backend_name(), kind.name());
            let preds = session.classify_trace(&trace.packets).unwrap();
            for (i, &key) in trace.keys.iter().enumerate() {
                let expect =
                    bnn::forward(&model, &PackedBits::from_u32(key)).get(0) as u32;
                assert_eq!(preds[i] & 1, expect, "{} pkt {i}", kind.name());
            }
            let stats = dep.stats("m").unwrap();
            assert_eq!(stats.packets, 64);
            assert_eq!(stats.version, 1);
            assert_eq!(stats.swaps, 0);
        }
    }

    #[test]
    fn registry_exposes_live_model_counters_and_version() {
        let model = BnnModel::random(32, &[16, 1], 44);
        let dep = deployment_for(&model, BackendKind::Batched);
        let reg = crate::obs::MetricsRegistry::new();
        dep.register_metrics(&reg, "deploy");

        // Collect-at-expose: the registry reads the same atomics the
        // session bumps, so values are live without re-registration.
        assert!(reg.expose().contains("deploy_model_m_packets 0"));
        let mut session = dep.session("m").unwrap();
        let mut gen = TraceGenerator::new(8);
        let trace = gen.generate(&TraceKind::UniformIps, 48);
        session.classify_trace(&trace.packets).unwrap();
        dep.swap_model("m", BnnModel::random(32, &[16, 1], 45)).unwrap();

        let exposed = reg.expose();
        assert!(exposed.contains("deploy_model_m_packets 48"), "{exposed}");
        assert!(exposed.contains("deploy_model_m_swaps 1"), "{exposed}");
        assert!(exposed.contains("deploy_model_m_version 2"), "{exposed}");
        assert!(exposed.contains("# TYPE deploy_model_m_version gauge"), "{exposed}");
    }

    #[test]
    fn swap_publishes_new_weights_to_open_sessions() {
        let a = BnnModel::random(32, &[16, 1], 1);
        let b = BnnModel::random(32, &[16, 1], 2);
        let dep = deployment_for(&a, BackendKind::Batched);
        let mut session = dep.session("m").unwrap();
        let mut gen = TraceGenerator::new(6);
        let trace = gen.generate(&TraceKind::UniformIps, 32);
        let refs: Vec<&[u8]> = trace.packets.iter().map(|p| p.as_slice()).collect();
        let mut out = Vec::new();

        assert_eq!(session.classify_batch(&refs, &mut out).unwrap(), 1);
        for (i, &key) in trace.keys.iter().enumerate() {
            let expect = bnn::forward(&a, &PackedBits::from_u32(key)).get(0) as u32;
            assert_eq!(out[i] & 1, expect, "pre-swap pkt {i}");
        }

        let v = dep.swap_model("m", b.clone()).unwrap();
        assert_eq!(v, 2);
        assert_eq!(dep.version("m").unwrap(), 2);
        assert_eq!(session.classify_batch(&refs, &mut out).unwrap(), 2);
        for (i, &key) in trace.keys.iter().enumerate() {
            let expect = bnn::forward(&b, &PackedBits::from_u32(key)).get(0) as u32;
            assert_eq!(out[i] & 1, expect, "post-swap pkt {i}");
        }
        assert_eq!(dep.stats("m").unwrap().swaps, 1);
        // Session stats survive the backend rebuild.
        assert_eq!(session.stats().packets, 64);
    }

    #[test]
    fn swap_rejects_architecture_changes_and_keeps_serving() {
        let a = BnnModel::random(32, &[16, 1], 3);
        let dep = deployment_for(&a, BackendKind::Batched);
        let err = dep.swap_model("m", BnnModel::random(32, &[32, 1], 4));
        assert!(err.is_err());
        assert_eq!(dep.version("m").unwrap(), 1, "failed swap must not publish");
        assert!(dep.swap_model("nope", a.clone()).is_err());
    }

    /// Compile honestly, then vandalize the program with an element
    /// whose slot cost blows the chip's VLIW budget. The compiler never
    /// emits this; the publish gate must still catch it (DESIGN.md §17).
    fn doctored_compile(model: &BnnModel) -> CompiledModel {
        use crate::rmt::{AluOp, ContainerId, Element, MicroOp, Src, StepKind};
        let mut compiled = Compiler::rmt().compile(model).unwrap();
        let over = compiled.chip.max_ops_per_element + 1;
        let ops = vec![
            MicroOp::Alu {
                dst: ContainerId(0),
                op: AluOp::Mov,
                a: Src::Imm(1),
                b: Src::Imm(0),
            };
            over
        ];
        compiled.program.elements.push(Element::new(
            "doctored-over-budget",
            StepKind::Other,
            ops,
        ));
        compiled
    }

    #[test]
    fn publish_gate_refuses_illegal_artifacts() {
        let model = BnnModel::random(32, &[16, 1], 51);
        let compiled = doctored_compile(&model);
        let err = ModelArtifact::new(Arc::new(model), Arc::new(compiled))
            .err()
            .expect("over-budget artifact must be refused");
        match err {
            Error::Verify(msg) => {
                assert!(msg.contains("op-budget"), "diagnostic names the kind: {msg}")
            }
            other => panic!("expected Error::Verify, got {other}"),
        }
    }

    #[test]
    fn failed_publish_leaves_slot_registry_and_serving_untouched() {
        let model = BnnModel::random(32, &[16, 1], 52);
        let dep = deployment_for(&model, BackendKind::Batched);
        let mut session = dep.session("m").unwrap();
        let mut gen = TraceGenerator::new(53);
        let trace = gen.generate(&TraceKind::UniformIps, 32);

        let entry = dep.entry("m").unwrap();
        let slot = entry.slot.as_ref().unwrap();
        let old_model =
            Arc::clone(&entry.model.lock().expect("model lock poisoned"));
        let new_model = Arc::new(BnnModel::random(32, &[16, 1], 54));
        let err = publish_verified(
            entry,
            slot,
            Arc::clone(&new_model),
            Arc::new(doctored_compile(&new_model)),
        );
        assert!(matches!(err, Err(Error::Verify(_))), "{err:?}");

        // The refused publish is a no-op on both halves of the swap:
        // slot version unchanged, registry still holds the old weights.
        assert_eq!(slot.version(), 1, "failed publish must not bump the slot");
        assert!(Arc::ptr_eq(
            &entry.model.lock().expect("model lock poisoned"),
            &old_model,
        ));
        // And the live path keeps serving the old model bit-exact.
        let preds = session.classify_trace(&trace.packets).unwrap();
        for (i, &key) in trace.keys.iter().enumerate() {
            let expect =
                bnn::forward(&model, &PackedBits::from_u32(key)).get(0) as u32;
            assert_eq!(preds[i] & 1, expect, "pkt {i} after refused publish");
        }
        assert_eq!(dep.stats("m").unwrap().swaps, 0);
    }

    #[test]
    fn engine_surfaces_the_model_version() {
        let a = BnnModel::random(32, &[16, 1], 7);
        let b = BnnModel::random(32, &[16, 1], 8);
        let dep = Deployment::builder()
            .model("m", a.clone())
            .workers(2)
            .build()
            .unwrap();
        let mut gen = TraceGenerator::new(9);
        let trace = gen.generate(&TraceKind::UniformIps, 100);
        let report = dep.serve_trace("m", &trace.packets).unwrap();
        assert_eq!(report.model_version, 1);
        assert_eq!(report.outputs.len(), 100);
        dep.swap_model("m", b.clone()).unwrap();
        let report = dep.serve_trace("m", &trace.packets).unwrap();
        assert_eq!(report.model_version, 2);
        for (i, &key) in trace.keys.iter().enumerate() {
            let expect = bnn::forward(&b, &PackedBits::from_u32(key)).get(0) as u32;
            assert_eq!(report.outputs[i] & 1, expect, "post-swap pkt {i}");
        }
    }

    #[test]
    fn build_validates_registry_and_lut() {
        assert!(Deployment::builder().build().is_err(), "no models");
        let m = BnnModel::random(32, &[16], 10);
        assert!(Deployment::builder()
            .model("a", m.clone())
            .model("a", m.clone())
            .build()
            .is_err());
        assert!(Deployment::builder()
            .model_with_id("a", 7, m.clone())
            .model_with_id("b", 7, m.clone())
            .build()
            .is_err());
        let err = match Deployment::builder()
            .backend(BackendKind::Lut)
            .model("a", m.clone())
            .build()
        {
            Err(e) => e,
            Ok(_) => panic!("lut backend without a table must fail"),
        };
        assert!(err.to_string().contains("lut"), "{err}");
    }

    #[test]
    fn isolated_and_keyed_sessions_are_mode_checked() {
        let m = BnnModel::random(32, &[16], 11);
        let isolated = Deployment::builder().model("a", m.clone()).build().unwrap();
        assert!(isolated.keyed_session().is_err());
        assert!(isolated.engine_keyed().is_err());
        let keyed = Deployment::builder()
            .extractor(FieldExtractor::PayloadAt { offset: 4 })
            .keyed(0)
            .model("a", m.clone())
            .model("b", BnnModel::random(32, &[16], 12))
            .build()
            .unwrap();
        assert!(keyed.is_keyed());
        assert!(keyed.session("a").is_err());
        assert!(keyed.engine("a").is_err());
        assert!(keyed.keyed_session().is_ok());
        // The reference backend replays one model's forward pass — it
        // cannot honor per-packet ids, so keyed mode rejects it.
        assert!(keyed.keyed_session_with(BackendKind::Reference).is_err());
        assert!(Deployment::builder()
            .extractor(FieldExtractor::PayloadAt { offset: 4 })
            .keyed(0)
            .backend(BackendKind::Reference)
            .model("a", m.clone())
            .build()
            .is_err());
    }

    #[test]
    fn keyed_malformed_packets_attribute_to_default_not_tenant() {
        // Regression (ISSUE 3 satellite): a truncated frame can carry a
        // perfectly legible tenant id and still be a parse-error lane
        // (the activations are cut off). The pipeline serves it as
        // output 0 — no tenant's weights ran — so the traffic counter
        // must go to the default model, not the id's tenant.
        let m_default = BnnModel::random(32, &[16], 91);
        let m_b = BnnModel::random(32, &[16], 92);
        let dep = Deployment::builder()
            .extractor(FieldExtractor::PayloadAt { offset: 4 })
            .keyed(0)
            .model_with_id("default", 1, m_default)
            .model_with_id("b", 2, m_b)
            .build()
            .unwrap();
        let mut session = dep.keyed_session().unwrap();
        // [id u32 LE][activation u32 LE] — 8 bytes parse, 6 don't.
        let mut good = 2u32.to_le_bytes().to_vec();
        good.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        let mut bad = 2u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[0xAA, 0xBB]); // tenant-b id, truncated body
        let refs: Vec<&[u8]> = vec![&good, &bad];
        let mut out = Vec::new();
        session.classify_batch(&refs, &mut out).unwrap();
        assert_eq!(out[1], 0, "parse-error lane classifies as 0");
        let b = dep.stats("b").unwrap();
        assert_eq!(b.packets, 1, "only the parseable frame is tenant-b traffic");
        assert_eq!(b.parse_errors, 0);
        let d = dep.stats("default").unwrap();
        assert_eq!(d.packets, 1, "the malformed frame attributes to the default");
        assert_eq!(d.parse_errors, 1);
    }

    #[test]
    fn sharded_engine_matches_the_engine_and_is_mode_checked() {
        let model = BnnModel::random(32, &[16, 1], 93);
        let dep = deployment_for(&model, BackendKind::Batched);
        let mut gen = TraceGenerator::new(94);
        let trace = gen.generate(&TraceKind::UniformIps, 200);
        let engine_out = dep.serve_trace("m", &trace.packets).unwrap().outputs;
        let report = dep.serve_trace_sharded("m", 3, &trace.packets).unwrap();
        assert_eq!(report.outputs, engine_out, "sharded ≡ single-engine");
        assert_eq!(report.version_min, 1);
        assert_eq!(report.version_max, 1);
        assert_eq!(report.dropped, 0);
        assert!(dep.sharded_engine("nope", 2).is_err());

        let keyed = Deployment::builder()
            .extractor(FieldExtractor::PayloadAt { offset: 4 })
            .keyed(0)
            .model("a", BnnModel::random(32, &[16], 95))
            .model("b", BnnModel::random(32, &[16], 96))
            .build()
            .unwrap();
        assert!(keyed.sharded_engine("a", 2).is_err(), "keyed mode check");
        assert!(keyed.sharded_engine_keyed(2).is_ok());
        assert!(dep.sharded_engine_keyed(2).is_err(), "isolated mode check");
    }

    #[test]
    fn swap_handle_scopes_swap_authority_to_one_model() {
        let a = BnnModel::random(32, &[16, 1], 97);
        let b = BnnModel::random(32, &[16, 1], 98);
        let dep = Arc::new(deployment_for(&a, BackendKind::Batched));
        assert!(SwapHandle::new(&dep, "nope").is_err(), "validated at creation");
        let handle = SwapHandle::new(&dep, "m").unwrap();
        assert_eq!(handle.model_name(), "m");
        assert_eq!(handle.version().unwrap(), 1);
        // Swaps through the handle are real swaps: versioned, visible
        // to the deployment, and architecture-checked.
        let cloned = handle.clone();
        assert_eq!(cloned.swap(b.clone()).unwrap(), 2);
        assert_eq!(dep.version("m").unwrap(), 2);
        assert_eq!(handle.stats().unwrap().swaps, 1);
        assert!(handle.swap(BnnModel::random(32, &[32, 1], 99)).is_err());
        assert_eq!(handle.version().unwrap(), 2, "rejected swap publishes nothing");
    }

    #[test]
    fn auto_ids_skip_explicitly_taken_ones() {
        let m = BnnModel::random(32, &[16], 13);
        // "a" takes id 1 explicitly; "b"'s auto id must skip 1.
        let dep = Deployment::builder()
            .extractor(FieldExtractor::PayloadAt { offset: 4 })
            .keyed(0)
            .model_with_id("a", 1, m.clone())
            .model("b", BnnModel::random(32, &[16], 14))
            .build()
            .unwrap();
        assert_eq!(dep.models(), vec!["a", "b"]);
        // Explicit ids registered AFTER an auto model must be avoided
        // by the auto-assignment too (two-pass resolution).
        let dep = Deployment::builder()
            .extractor(FieldExtractor::PayloadAt { offset: 4 })
            .keyed(0)
            .model("c", m.clone())
            .model_with_id("d", 0, BnnModel::random(32, &[16], 15))
            .build()
            .unwrap();
        assert_eq!(dep.models(), vec!["c", "d"]);
    }
}
