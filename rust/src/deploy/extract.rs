//! Typed input-field extraction (DESIGN.md §11).
//!
//! The paper leaves "which header bits feed the BNN" open ("e.g., the
//! destination IP address of the packet", §2). Before this module every
//! consumer spelled that choice as a raw byte offset
//! (`InputEncoding::BigEndianField { offset: IPV4_SRC_OFFSET }` copied
//! into apps, benches, and the CLI). [`FieldExtractor`] names the
//! choices instead and owns the offset arithmetic; the deployment
//! builder turns one into the compiler's [`InputEncoding`].

use crate::compiler::InputEncoding;
use crate::error::{Error, Result};
use crate::net::packet::{IPV4_DST_OFFSET, IPV4_SRC_OFFSET};
use crate::net::N2NET_PAYLOAD_OFFSET;

/// Where a deployment reads the model's input activation vector from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FieldExtractor {
    /// IPv4 source address (the DDoS use case). Requires `in_bits == 32`.
    #[default]
    SrcIp,
    /// IPv4 destination address (the paper's §2 example). Requires
    /// `in_bits == 32`.
    DstIp,
    /// Packed little-endian activation words in the N2Net UDP payload
    /// (offset 42 = after Eth+IPv4+UDP). Any activation width.
    Payload,
    /// Packed little-endian activation words at a custom byte offset
    /// (raw buffers, custom encapsulations).
    PayloadAt { offset: usize },
    /// A single 32-bit big-endian header field at a custom byte offset
    /// (custom header slices). Requires `in_bits == 32`.
    Field32 { offset: usize },
}

impl FieldExtractor {
    /// The compiler encoding this extractor stands for.
    pub fn encoding(self) -> InputEncoding {
        match self {
            FieldExtractor::SrcIp => {
                InputEncoding::BigEndianField { offset: IPV4_SRC_OFFSET }
            }
            FieldExtractor::DstIp => {
                InputEncoding::BigEndianField { offset: IPV4_DST_OFFSET }
            }
            FieldExtractor::Payload => {
                InputEncoding::PayloadLe { offset: N2NET_PAYLOAD_OFFSET }
            }
            FieldExtractor::PayloadAt { offset } => InputEncoding::PayloadLe { offset },
            FieldExtractor::Field32 { offset } => {
                InputEncoding::BigEndianField { offset }
            }
        }
    }

    /// Human-readable spelling (also the CLI grammar of [`parse`]).
    ///
    /// [`parse`]: FieldExtractor::parse
    pub fn describe(self) -> String {
        match self {
            FieldExtractor::SrcIp => "src-ip".into(),
            FieldExtractor::DstIp => "dst-ip".into(),
            FieldExtractor::Payload => "payload".into(),
            FieldExtractor::PayloadAt { offset } => format!("payload@{offset}"),
            FieldExtractor::Field32 { offset } => format!("field@{offset}"),
        }
    }

    /// Parse a CLI spelling: `src-ip`, `dst-ip`, `payload`,
    /// `payload@OFFSET`, or `field@OFFSET`.
    pub fn parse(s: &str) -> Result<Self> {
        let offset_of = |spec: &str| -> Result<usize> {
            spec.parse().map_err(|_| {
                Error::Config(format!("bad extractor offset {spec:?} in {s:?}"))
            })
        };
        match s {
            "src-ip" => Ok(FieldExtractor::SrcIp),
            "dst-ip" => Ok(FieldExtractor::DstIp),
            "payload" => Ok(FieldExtractor::Payload),
            other => {
                if let Some(spec) = other.strip_prefix("payload@") {
                    Ok(FieldExtractor::PayloadAt { offset: offset_of(spec)? })
                } else if let Some(spec) = other.strip_prefix("field@") {
                    Ok(FieldExtractor::Field32 { offset: offset_of(spec)? })
                } else {
                    Err(Error::Config(format!(
                        "unknown extractor {other:?} \
                         (expected src-ip|dst-ip|payload|payload@N|field@N)"
                    )))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extractors_name_the_documented_offsets() {
        assert_eq!(
            FieldExtractor::SrcIp.encoding(),
            InputEncoding::BigEndianField { offset: 26 }
        );
        assert_eq!(
            FieldExtractor::DstIp.encoding(),
            InputEncoding::BigEndianField { offset: 30 }
        );
        assert_eq!(
            FieldExtractor::Payload.encoding(),
            InputEncoding::PayloadLe { offset: 42 }
        );
        assert_eq!(
            FieldExtractor::PayloadAt { offset: 4 }.encoding(),
            InputEncoding::PayloadLe { offset: 4 }
        );
        assert_eq!(
            FieldExtractor::Field32 { offset: 30 }.encoding(),
            InputEncoding::BigEndianField { offset: 30 }
        );
    }

    #[test]
    fn parse_roundtrips_every_spelling() {
        for x in [
            FieldExtractor::SrcIp,
            FieldExtractor::DstIp,
            FieldExtractor::Payload,
            FieldExtractor::PayloadAt { offset: 0 },
            FieldExtractor::Field32 { offset: 26 },
        ] {
            assert_eq!(FieldExtractor::parse(&x.describe()).unwrap(), x);
        }
        assert!(FieldExtractor::parse("tcp-flags").is_err());
        assert!(FieldExtractor::parse("payload@x").is_err());
        assert_eq!(FieldExtractor::default(), FieldExtractor::SrcIp);
    }
}
