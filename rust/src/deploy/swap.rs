//! RCU-style publication of compiled models (DESIGN.md §11).
//!
//! Hot-swap protocol: `swap_model` recompiles **off the hot path**, then
//! [`SwapCell::store`] atomically publishes the new
//! `Arc<`[`ModelArtifact`]`>` and bumps a monotone version counter.
//! Readers ([`crate::deploy::Session`]s and engine workers) keep serving
//! the old `Arc` until their next batch boundary, where a single atomic
//! [`SwapCell::version`] peek tells them to reload — no reader ever
//! blocks on a writer for more than the microseconds it takes to clone
//! an `Arc`, and no in-flight batch is drained or torn: a batch runs
//! wholly against one artifact, so every packet's prediction is
//! bit-exact under either the old or the new model
//! (`tests/prop_hotswap.rs` holds this under concurrency).
//!
//! `SwapCell` is the std-only equivalent of the `arc-swap` crate: a
//! `Mutex<Arc<T>>` guarding the pointer plus an `AtomicU64` version for
//! the lock-free fast-path check. The lock is held only to clone or
//! replace the `Arc` (never across compilation or inference), which is
//! the RCU grace-period story collapsed to reference counting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::backend::SpecializedProgram;
use crate::bnn::BnnModel;
use crate::compiler::CompiledModel;
use crate::error::{Error, Result};
use crate::telemetry::Counter;

/// Atomically replaceable `Arc<T>` with a monotone version counter.
pub struct SwapCell<T> {
    current: Mutex<Arc<T>>,
    version: AtomicU64,
}

impl<T> SwapCell<T> {
    /// Wrap an initial value at version 1.
    pub fn new(value: Arc<T>) -> Self {
        Self { current: Mutex::new(value), version: AtomicU64::new(1) }
    }

    /// Snapshot the current value and its version (consistent pair).
    pub fn load(&self) -> (Arc<T>, u64) {
        let guard = self.current.lock().expect("SwapCell poisoned");
        (Arc::clone(&guard), self.version.load(Ordering::Acquire))
    }

    /// Monotone version peek — one atomic load, no lock. Readers use
    /// this per batch to decide whether to reload.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Publish a new value; returns the new version. The version is
    /// bumped while the pointer lock is held so `load` never observes a
    /// (value, version) pair that was not published together.
    pub fn store(&self, value: Arc<T>) -> u64 {
        let mut guard = self.current.lock().expect("SwapCell poisoned");
        *guard = value;
        self.version.fetch_add(1, Ordering::AcqRel) + 1
    }
}

/// Everything a backend needs to serve one published model: the
/// compiled pipeline program, the source weights (the reference
/// backend replays the forward pass from them), and the deploy-time
/// specialization (DESIGN.md §15). Swapped as one unit so program,
/// weights, and specialized kernels can never skew.
pub struct ModelArtifact {
    pub model: Arc<BnnModel>,
    pub compiled: Arc<CompiledModel>,
    /// Pre-built specializing-codegen program, shared by every session
    /// and shard worker serving this artifact. Built here — publish
    /// time, off the hot path — so a hot-swap or a runtime backend
    /// switch to `specialized` never compiles on a serving thread.
    /// `None` when the program cannot be specialized (keyed tables).
    pub specialized: Option<Arc<SpecializedProgram>>,
}

impl ModelArtifact {
    /// Bundle a compiled model for publication, pre-specializing it.
    ///
    /// This is the **publish gate** (DESIGN.md §17): the artifact is
    /// statically verified (`compiler::verify` — dataflow, overflow,
    /// chip budgets, translation-validated optimizer run) and refused
    /// with [`Error::Verify`] on any error-severity violation, so an
    /// illegal program can never reach a [`ModelSlot`] and the serving
    /// model stays undisturbed. Keyed programs simply skip
    /// specialization (`specialized: None`); the backend selection
    /// path reports the error if such a deployment asks for the
    /// specialized backend.
    pub fn new(model: Arc<BnnModel>, compiled: Arc<CompiledModel>) -> Result<Self> {
        let report = compiled.verify();
        if report.has_errors() {
            return Err(Error::Verify(format!(
                "refusing to publish artifact with {} violation(s): {}",
                report.n_errors(),
                report.error_digest()
            )));
        }
        let specialized = match SpecializedProgram::build(&compiled) {
            Ok(s) => Some(Arc::new(s)),
            // A translation-validation failure is a publish blocker …
            Err(Error::Verify(m)) => return Err(Error::Verify(m)),
            // … but "cannot specialize" (keyed tables) is not: those
            // artifacts serve through the interpreted backends.
            Err(_) => None,
        };
        Ok(Self { model, compiled, specialized })
    }
}

/// A named publication slot: the unit of hot-swap. One per model in an
/// isolated deployment; one for the whole keyed-table program in a
/// keyed deployment.
pub struct ModelSlot {
    name: String,
    cell: SwapCell<ModelArtifact>,
}

impl ModelSlot {
    pub fn new(name: impl Into<String>, artifact: ModelArtifact) -> Self {
        Self { name: name.into(), cell: SwapCell::new(Arc::new(artifact)) }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Current artifact + version (consistent pair).
    pub fn load(&self) -> (Arc<ModelArtifact>, u64) {
        self.cell.load()
    }

    /// Lock-free monotone version peek (the per-batch fast path).
    pub fn version(&self) -> u64 {
        self.cell.version()
    }

    /// Atomically publish a recompiled artifact; returns the new version.
    pub fn publish(&self, artifact: ModelArtifact) -> u64 {
        self.cell.store(Arc::new(artifact))
    }
}

/// Per-model serving counters (session path; the engine keeps its own
/// [`crate::telemetry::EngineMetrics`]).
#[derive(Debug, Default)]
pub struct ModelCounters {
    /// Packets routed to this model, malformed ones included (those
    /// also count in `parse_errors`).
    pub packets: Counter,
    /// Malformed packets observed while serving; in keyed mode these
    /// are attributed to the default model (the backend reports parse
    /// errors in aggregate, not per lane).
    pub parse_errors: Counter,
    /// Successful hot-swaps published for this model.
    pub swaps: Counter,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_are_monotone_and_pairs_consistent() {
        let cell = SwapCell::new(Arc::new(7u32));
        assert_eq!(cell.version(), 1);
        let (v0, ver0) = cell.load();
        assert_eq!((*v0, ver0), (7, 1));
        assert_eq!(cell.store(Arc::new(8)), 2);
        assert_eq!(cell.store(Arc::new(9)), 3);
        let (v, ver) = cell.load();
        assert_eq!((*v, ver), (9, 3));
    }

    #[test]
    fn concurrent_stores_and_loads_never_tear() {
        let cell = Arc::new(SwapCell::new(Arc::new(0u64)));
        std::thread::scope(|s| {
            let writer = Arc::clone(&cell);
            s.spawn(move || {
                for i in 1..=100u64 {
                    writer.store(Arc::new(i));
                }
            });
            for _ in 0..4 {
                let reader = Arc::clone(&cell);
                s.spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..200 {
                        let (_, ver) = reader.load();
                        assert!(ver >= last, "version went backwards");
                        last = ver;
                    }
                });
            }
        });
        assert_eq!(cell.version(), 101);
    }
}
