//! Serving handles over a deployment's published models.
//!
//! A [`Session`] is the single-threaded classify handle: it owns one
//! [`InferenceBackend`] built from the slot's current artifact and
//! re-checks the slot's version with one atomic load per batch, so a
//! [`swap`](crate::deploy::Deployment::swap_model) published by any
//! thread is picked up at the next batch boundary without ever tearing
//! a batch. Sessions are `Send` (move one into each worker thread);
//! create one session per thread rather than sharing.

use std::sync::Arc;

use crate::backend::{
    make_backend, BackendKind, InferenceBackend, LutBackend, SpecializedBackend,
};
use crate::baseline::LutClassifier;
use crate::error::{Error, Result};
use crate::rmt::PipelineStats;

use super::swap::{ModelArtifact, ModelCounters, ModelSlot};

/// One user-facing hint for the `lut`-without-table misconfiguration,
/// shared by the build-time check and the session-open path so the
/// guidance cannot drift.
pub(crate) const LUT_TABLE_HINT: &str =
    "backend \"lut\" needs a populated LUT table: pass one to \
     Deployment::builder().lut(..) (the CLI run/serve paths build \
     it from the trained DdosDoc blacklist when available)";

/// Build the backend serving one published artifact. This is the only
/// place the deployment layer calls the low-level
/// [`crate::backend::make_backend`] constructor.
pub(crate) fn backend_for_artifact(
    kind: BackendKind,
    artifact: &ModelArtifact,
    lut: Option<&Arc<LutClassifier>>,
) -> Result<Box<dyn InferenceBackend>> {
    match kind {
        BackendKind::Lut => match lut {
            Some(l) => Ok(Box::new(LutBackend::new(l.as_ref().clone()))),
            None => Err(Error::Config(LUT_TABLE_HINT.into())),
        },
        // Reuse the specialization built at publish time; falling back
        // to `make_backend` (which specializes on the spot) only
        // surfaces the lowering error for unspecializable programs.
        BackendKind::Specialized => match &artifact.specialized {
            Some(spec) => Ok(Box::new(SpecializedBackend::from_parts(
                Arc::clone(&artifact.compiled),
                Arc::clone(spec),
            ))),
            None => make_backend(kind, &artifact.compiled, Some(&artifact.model)),
        },
        _ => make_backend(kind, &artifact.compiled, Some(&artifact.model)),
    }
}

/// Shared trace loop: classify `packets` in `chunk`-sized batches via
/// `run` (one `classify_batch`-shaped call per chunk), concatenating
/// the output words. Malformed packets classify as 0 without failing
/// the run; hot-swaps are picked up between chunks.
fn classify_chunked<F>(packets: &[Vec<u8>], chunk: usize, mut run: F) -> Result<Vec<u32>>
where
    F: FnMut(&[&[u8]], &mut Vec<u32>) -> Result<u64>,
{
    let mut words = Vec::with_capacity(packets.len());
    let mut buf = Vec::new();
    for c in packets.chunks(chunk.max(1)) {
        let refs: Vec<&[u8]> = c.iter().map(|p| p.as_slice()).collect();
        run(refs.as_slice(), &mut buf)?;
        words.extend_from_slice(&buf);
    }
    Ok(words)
}

/// A classify handle bound to one model slot.
pub struct Session {
    slot: Arc<ModelSlot>,
    kind: BackendKind,
    lut: Option<Arc<LutClassifier>>,
    /// Per-model counters to bump (None in keyed mode, where
    /// [`KeyedSession`] attributes per packet instead).
    counters: Option<Arc<ModelCounters>>,
    /// Version of the artifact the current backend was built from.
    version: u64,
    backend: Box<dyn InferenceBackend>,
    /// Stats of backends retired by hot-swaps, folded into totals.
    retired: PipelineStats,
}

impl Session {
    pub(crate) fn open(
        slot: Arc<ModelSlot>,
        kind: BackendKind,
        lut: Option<Arc<LutClassifier>>,
        counters: Option<Arc<ModelCounters>>,
    ) -> Result<Self> {
        let (artifact, version) = slot.load();
        let backend = backend_for_artifact(kind, &artifact, lut.as_ref())?;
        Ok(Self {
            slot,
            kind,
            lut,
            counters,
            version,
            backend,
            retired: PipelineStats::default(),
        })
    }

    /// Pick up a published swap: one atomic version peek; on change,
    /// retire the current backend (folding its stats) and rebuild from
    /// the new artifact.
    fn refresh(&mut self) -> Result<()> {
        if self.slot.version() == self.version {
            return Ok(());
        }
        let (artifact, version) = self.slot.load();
        let fresh = backend_for_artifact(self.kind, &artifact, self.lut.as_ref())?;
        let old = std::mem::replace(&mut self.backend, fresh);
        let s = old.stats();
        self.retired.packets += s.packets;
        self.retired.element_executions += s.element_executions;
        self.retired.parse_errors += s.parse_errors;
        self.version = version;
        Ok(())
    }

    /// Classify a batch: one output word per packet (the backend trait's
    /// low-output-bits convention; malformed packets yield 0). Returns
    /// the model version that served the whole batch — swaps published
    /// mid-batch take effect at the next call.
    pub fn classify_batch(
        &mut self,
        packets: &[&[u8]],
        out: &mut Vec<u32>,
    ) -> Result<u64> {
        self.refresh()?;
        let errs_before = self.backend.stats().parse_errors;
        self.backend.run_batch(packets, out)?;
        if let Some(c) = &self.counters {
            let errs = self.backend.stats().parse_errors.saturating_sub(errs_before);
            c.parse_errors.add(errs);
            // `packets` counts routed packets (malformed included — those
            // also show in parse_errors), matching keyed attribution.
            c.packets.add(packets.len() as u64);
        }
        Ok(self.version)
    }

    /// Chunk size the current backend amortizes best at.
    pub(crate) fn preferred_chunk(&self) -> usize {
        self.backend.caps().preferred_batch.max(1)
    }

    /// Classify a whole stream in backend-preferred batches; malformed
    /// packets classify as 0 without failing the run. Swaps are picked
    /// up between chunks.
    pub fn classify_trace(&mut self, packets: &[Vec<u8>]) -> Result<Vec<u32>> {
        let chunk = self.preferred_chunk();
        classify_chunked(packets, chunk, |refs, buf| self.classify_batch(refs, buf))
    }

    /// Classify one frame, treating a malformed frame as an error (the
    /// switch would drop it, and a single-packet caller should know).
    pub fn classify_one(&mut self, frame: &[u8]) -> Result<u32> {
        let errs_before = self.stats().parse_errors;
        let mut out = Vec::with_capacity(1);
        self.classify_batch(&[frame], &mut out)?;
        if self.stats().parse_errors > errs_before {
            return Err(Error::Parse("malformed frame".into()));
        }
        Ok(out.first().copied().unwrap_or(0))
    }

    /// Model version currently serving this session.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Short backend name
    /// (`scalar`/`batched`/`reference`/`lut`/`specialized`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.caps().name
    }

    /// Cumulative stats across every backend this session has driven
    /// (hot-swaps retire backends; their counts are folded in).
    pub fn stats(&self) -> PipelineStats {
        let s = self.backend.stats();
        PipelineStats {
            packets: self.retired.packets + s.packets,
            element_executions: self.retired.element_executions + s.element_executions,
            parse_errors: self.retired.parse_errors + s.parse_errors,
        }
    }
}

/// Classify handle for a keyed (shared-pipeline multi-model)
/// deployment: one program serves every model, a packet header field
/// selects the weights per packet. Attribution of per-model packet
/// counters happens here by parsing the same id field the pipeline
/// matches on, with the pipeline's own parse semantics: a frame the
/// published program cannot fully parse is a parse-error lane (output
/// 0, served by no tenant's weights), so it attributes to the default
/// model even when a legible tenant id happens to sit at `id_offset` —
/// a truncated frame must never inflate a tenant's traffic counters.
/// An unknown id likewise attributes to the default model, matching the
/// table-miss semantics.
pub struct KeyedSession {
    session: Session,
    id_offset: usize,
    /// Shortest frame the published program parses; anything shorter is
    /// a parse-error lane. The parser's extracts are the only parse
    /// failure mode, and each is a pure length check, so this threshold
    /// is exact, not a heuristic — and it is fixed for the deployment's
    /// lifetime: hot-swaps reject architecture changes, and the parser
    /// is a pure function of the architecture plus the (fixed)
    /// extractor and id layout.
    min_frame_len: usize,
    /// (model id, counters) in registration order; index 0 = default.
    by_id: Vec<(u32, Arc<ModelCounters>)>,
}

impl KeyedSession {
    pub(crate) fn open(
        slot: Arc<ModelSlot>,
        kind: BackendKind,
        lut: Option<Arc<LutClassifier>>,
        id_offset: usize,
        by_id: Vec<(u32, Arc<ModelCounters>)>,
    ) -> Result<Self> {
        let min_frame_len = slot.load().0.compiled.parser.min_packet_len();
        Ok(Self {
            session: Session::open(slot, kind, lut, None)?,
            id_offset,
            min_frame_len,
            by_id,
        })
    }

    fn counters_index(&self, pkt: &[u8]) -> usize {
        // Parse-error lanes (frames the program cannot parse) belong to
        // the default model regardless of what bytes sit where the id
        // would be.
        if pkt.len() < self.min_frame_len {
            return 0;
        }
        self.id_offset
            .checked_add(4)
            .and_then(|end| pkt.get(self.id_offset..end))
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .and_then(|id| self.by_id.iter().position(|(k, _)| *k == id))
            .unwrap_or(0)
    }

    /// Classify a mixed-model batch; returns the program version that
    /// served it (see [`Session::classify_batch`]).
    pub fn classify_batch(
        &mut self,
        packets: &[&[u8]],
        out: &mut Vec<u32>,
    ) -> Result<u64> {
        let errs_before = self.session.stats().parse_errors;
        let version = self.session.classify_batch(packets, out)?;
        for pkt in packets {
            self.by_id[self.counters_index(pkt)].1.packets.inc();
        }
        let errs = self.session.stats().parse_errors.saturating_sub(errs_before);
        if let Some((_, default)) = self.by_id.first() {
            default.parse_errors.add(errs);
        }
        Ok(version)
    }

    /// Classify a whole mixed-model stream in backend-preferred batches.
    pub fn classify_trace(&mut self, packets: &[Vec<u8>]) -> Result<Vec<u32>> {
        let chunk = self.session.preferred_chunk();
        classify_chunked(packets, chunk, |refs, buf| self.classify_batch(refs, buf))
    }

    /// Program version currently serving this session.
    pub fn version(&self) -> u64 {
        self.session.version()
    }

    /// Short backend name.
    pub fn backend_name(&self) -> &'static str {
        self.session.backend_name()
    }

    /// Cumulative stats (all models — the program is shared).
    pub fn stats(&self) -> PipelineStats {
        self.session.stats()
    }
}
