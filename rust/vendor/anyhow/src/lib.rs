//! Minimal, offline, source-compatible subset of the `anyhow` crate
//! (DESIGN.md §Substitutions). Implements exactly what the `n2net`
//! binary and examples use:
//!
//! * [`Error`] — an opaque error with a context chain; `{:#}` formats
//!   the whole chain (`msg: cause: cause`), `{}` just the newest
//!   message, `{:?}` a multi-line report.
//! * [`Result`] — `Result<T, Error>` alias.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`bail!`], [`ensure!`], [`anyhow!`] macros.
//!
//! Unlike the real crate there is no backtrace capture and no downcast
//! support — none of which the offline build needs.

use std::fmt;

/// An error with a chain of context messages (newest first).
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Build from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain on one line.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for c in rest {
                        write!(f, "\n    {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

/// Any std error converts, preserving its source chain as context.
/// (`Error` itself deliberately does not implement `std::error::Error`,
/// exactly like the real `anyhow`, so this blanket impl is coherent.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`; the second parameter mirrors the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Error::from(io_err()).context("loading weights");
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = Context::context(r, "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");
        let o: Option<u32> = None;
        let e = Context::with_context(o, || "missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert!(format!("{:#}", f(12).unwrap_err()).contains("too big"));
        let e = anyhow!("ad hoc {}", 5);
        assert_eq!(format!("{e}"), "ad hoc 5");
    }
}
