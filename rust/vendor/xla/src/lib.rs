//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The build environment has no XLA toolchain, so this crate mirrors the
//! small API surface `n2net::runtime` consumes and fails — cleanly, at
//! *runtime*, from `PjRtClient::cpu()` — with an explanatory error. All
//! downstream code (the PJRT oracle, `n2net run`/`selftest`) compiles
//! unchanged and reports "PJRT unavailable" instead of linking XLA.
//!
//! Swap this path dependency for the real `xla` crate to get the actual
//! golden-oracle execution; nothing else in the tree changes.

use std::fmt;

/// Error type matching the real crate's role in signatures.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT/XLA is unavailable in this offline build (stub crate at \
         rust/vendor/xla); install the real `xla` bindings to run the \
         golden oracle"
            .to_string(),
    ))
}

/// Stub of a host literal (typed array value).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal. The stub keeps no data — any attempt to
    /// execute or read it back errors.
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Stub of a device buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Stub of an HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Stub of a computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of the PJRT client. `cpu()` is the single failure point every
/// runtime path funnels through.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Stub of a compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Matches the real crate's generic-over-input-kind signature.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1u32]).reshape(&[1]).is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT"));
    }
}
