//! Timing-model properties (ISSUE 7 acceptance):
//!
//! T1. Modeled latency is monotone: more recirculation passes or more
//!     occupied stages never make a packet faster.
//! T2. A 1-pass compiled program costs EXACTLY parser + stages +
//!     deparser cycles — no hidden constants — and a recirculating one
//!     exactly adds full traversals plus the loop penalty.
//! T3. Modeled-latency SLO detection is a pure function of the packet
//!     counters: scrambling every wall-clock-derived field of the
//!     signal windows (batch counts, host latency percentiles) changes
//!     nothing, and two identical sim runs under the modeled detector
//!     produce identical reaction windows regardless of host jitter.

use std::sync::Arc;

use n2net::bnn::BnnModel;
use n2net::compiler::Compiler;
use n2net::controlplane::{
    prefix_classifier, Detector, LatencySloDetector, ModelBank, Policy, Sim,
    SimConfig, SignalWindow,
};
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::net::{Scenario, ScenarioSequence};
use n2net::telemetry::CLASS_BUCKETS;
use n2net::timing::{analyze_compiled, recirculation_passes, ChipTiming, ModeledSlo};
use n2net::util::prop;
use n2net::util::rng::Rng;

// T1 — latency monotonicity in both axes of the cycle formula.

#[test]
fn prop_t1_packet_cycles_monotone_in_stages_and_passes() {
    let t = ChipTiming::rmt();
    prop::check("timing-monotone", prop::default_cases(), |rng| {
        let stages = 1 + rng.gen_range(0, 256);
        let passes = 1 + rng.gen_range(0, 8);
        let base = t.packet_cycles(stages, passes);
        if t.packet_cycles(stages + 1, passes) <= base {
            return Err(format!(
                "adding a stage did not cost cycles at ({stages}, {passes})"
            ));
        }
        if t.packet_cycles(stages, passes + 1) <= base {
            return Err(format!(
                "adding a pass did not cost cycles at ({stages}, {passes})"
            ));
        }
        Ok(())
    });
}

// T2 — exact cycle accounting on real compiled programs.

#[test]
fn prop_t2_compiled_program_cycles_are_exactly_the_traversal_sum() {
    prop::check("timing-exact-cycles", prop::default_cases().min(16), |rng| {
        // Intermediate activation widths must be powers of two in the
        // paper's Table 1 range, like every model the compiler accepts.
        let in_bits = prop::pow2_in(rng, 16, 256);
        let hidden = prop::pow2_in(rng, 16, 128);
        let model = BnnModel::random(in_bits, &[hidden, 1], rng.next_u64());
        let c = Compiler::rmt().compile(&model).map_err(|e| e.to_string())?;
        let t = ChipTiming::for_chip(&c.chip);
        let r = analyze_compiled(&c, &t).map_err(|e| e.to_string())?;
        let passes = recirculation_passes(r.elements, &c.chip)
            .map_err(|e| e.to_string())?;
        if r.passes != passes {
            return Err(format!("passes {} != {passes}", r.passes));
        }
        let expect = passes as u64 * (t.parser_cycles + t.deparser_cycles)
            + r.elements as u64 * t.stage_cycles
            + (passes as u64 - 1) * t.recirculation_cycles;
        if r.cycles_per_packet != expect {
            return Err(format!(
                "N={in_bits} M={hidden}: {} cycles, traversal sum says {expect}",
                r.cycles_per_packet
            ));
        }
        // 1-pass has no recirculation term at all; line rate is intact.
        if passes == 1 {
            let one = t.parser_cycles
                + r.elements as u64 * t.stage_cycles
                + t.deparser_cycles;
            if r.cycles_per_packet != one {
                return Err(format!("1-pass cost {} != {one}", r.cycles_per_packet));
            }
            if r.modeled_pps != t.line_rate_pps() {
                return Err("1-pass program must keep line rate".into());
            }
        }
        Ok(())
    });
}

// T3 — modeled detection ignores every host-derived field.

fn window(index: u64, per_shard: Vec<u64>, rng: &mut Rng) -> SignalWindow {
    let packets: u64 = per_shard.iter().sum();
    let mut classes = [0u64; CLASS_BUCKETS];
    classes[0] = packets;
    SignalWindow {
        index,
        per_shard_packets: per_shard,
        packets,
        // Host-jitter-dependent fields get random garbage: a modeled
        // detector must not read any of them.
        batches: rng.gen_range(0, 1_000) as u64,
        parse_errors: 0,
        dropped: 0,
        backpressure_waits: 0,
        classes,
        version_min: 1,
        version_max: 1,
        latency_p50_ns: rng.gen_f64() * 1e12,
        latency_p99_ns: rng.gen_f64() * 1e12,
    }
}

#[test]
fn prop_t3_modeled_detection_is_a_pure_function_of_packet_counts() {
    let slo = ModeledSlo { fill_cycles: 410, slots_per_packet: 1, clock_hz: 960e6 };
    prop::check("timing-modeled-purity", prop::default_cases(), |rng| {
        let shards = 1 + rng.gen_range(0, 4);
        let nominal = 64 + rng.gen_range(0, 512) as u64;
        let mut a = LatencySloDetector::modeled(slo, nominal, 1.5);
        let mut b = LatencySloDetector::modeled(slo, nominal, 1.5);
        for i in 0..12u64 {
            // Same per-shard load, independently scrambled host fields.
            let load: Vec<u64> =
                (0..shards).map(|_| rng.gen_range(0, 2_000) as u64).collect();
            let da = a.observe(&window(i, load.clone(), rng));
            let db = b.observe(&window(i, load, rng));
            let (sa, sb) = (
                da.as_ref().map(|d| d.severity),
                db.as_ref().map(|d| d.severity),
            );
            if sa != sb {
                return Err(format!("window {i}: {sa:?} != {sb:?}"));
            }
            if let Some(d) = da {
                if !d.detail.contains("modeled") {
                    return Err(format!("detail not modeled-sourced: {}", d.detail));
                }
            }
        }
        Ok(())
    });
}

// T3 (sim level) — the full closed loop fires identically across runs,
// reacting to shard skew the packet counters prove, never to host time.

fn modeled_sim(dep: &Arc<Deployment>, cfg: SimConfig) -> Sim {
    let compiled = dep.compiled("live").unwrap();
    let t = ChipTiming::for_chip(&compiled.chip);
    let report = analyze_compiled(&compiled, &t).unwrap();
    let nominal = (cfg.window_packets / cfg.n_shards) as u64;
    let detectors: Vec<Box<dyn Detector>> =
        vec![Box::new(LatencySloDetector::modeled(report.slo(), nominal, 1.5))];
    let bank = ModelBank::new("day", prefix_classifier(0xC0A8_0000));
    let policy = Policy::parse("on latency-slo do alert cooldown=4").unwrap();
    Sim::with_detectors(dep, "live", bank, policy, cfg, detectors).unwrap()
}

#[test]
fn modeled_slo_sim_fires_on_shard_skew_with_host_independent_windows() {
    let dep = Arc::new(
        Deployment::builder()
            .extractor(FieldExtractor::SrcIp)
            .model("live", prefix_classifier(0xC0A8_0000))
            .build()
            .unwrap(),
    );
    let cfg = SimConfig { n_shards: 2, window_packets: 512, seed: 23 };
    // Balanced uniform prefix (≈256 pkts/shard, under the 1.5×256
    // breach line), then a heavy hitter pinning ~90% of each window
    // onto one flow-affine shard (≈460 pkts ≫ 384).
    let seq = ScenarioSequence::new(vec![
        (Scenario::Uniform, 512 * 4),
        (Scenario::ZipfHeavyHitter { n_flows: 16, hitter_share: 0.9 }, 512 * 6),
    ]);

    let run = |_: u64| {
        let mut sim = modeled_sim(&dep, cfg);
        let report = sim.run_sequence(&seq).unwrap();
        let fired: Vec<u64> = report
            .ticks
            .iter()
            .flat_map(|t| &t.events)
            .map(|e| e.window)
            .collect();
        (fired, report)
    };
    let (fired_a, report_a) = run(0);
    let (fired_b, _) = run(1);

    // Identical reaction windows on every run: the modeled detector
    // reads only deterministic packet counters, never host time.
    assert_eq!(fired_a, fired_b, "modeled detections must be host-independent");
    assert!(!fired_a.is_empty(), "skew never detected:\n{}", report_a.render());

    // Every firing lands in the skewed segment (windows are globally
    // indexed per run; the uniform prefix is the first 4 of each run's
    // 10 windows).
    let first = report_a.ticks.first().unwrap().window.index;
    for w in &fired_a {
        assert!(
            *w >= first + 4,
            "alert in the balanced prefix (w{w}, run starts at w{first}):\n{}",
            report_a.render()
        );
    }
    assert!(report_a.swaps.is_empty(), "alert-only policy must not swap");
}
