//! AOT bridge integration: artifacts built by `make artifacts` load and
//! execute via PJRT, agree with the golden vectors baked at export time,
//! and agree bit-for-bit with the Rust reference forward pass.
//!
//! These tests require `artifacts/` (they are the point of the bridge);
//! they fail with a clear message if `make artifacts` has not run.

use n2net::bnn::{self, PackedBits};
use n2net::runtime::Oracle;
use n2net::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    Oracle::default_dir()
}

#[test]
fn oracle_loads_and_passes_golden_self_test() {
    let oracle = Oracle::load(artifacts_dir()).expect("run `make artifacts` first");
    assert_eq!(oracle.platform(), "cpu");
    oracle.self_test().expect("golden vectors must match bit-for-bit");
}

#[test]
fn oracle_matches_rust_reference_forward() {
    let oracle = Oracle::load(artifacts_dir()).expect("run `make artifacts` first");
    let (model, _doc) =
        bnn::load_weights(artifacts_dir().join("weights.json")).unwrap();
    assert_eq!(oracle.n_layers(), model.spec.n_layers());

    // 200 random 32-bit inputs — chunking also exercises padding.
    let mut rng = Rng::seed_from_u64(0xA0A0);
    let inputs: Vec<Vec<u32>> = (0..200).map(|_| vec![rng.next_u32()]).collect();
    let out = oracle.run(&inputs).unwrap();

    for (i, input) in inputs.iter().enumerate() {
        let x = PackedBits::from_u32(input[0]);
        let traces = bnn::forward_trace(&model, &x);
        for (l, t) in traces.iter().enumerate() {
            assert_eq!(
                out.sign_packed[l][i],
                t.signs.words().to_vec(),
                "layer {l} sign bits diverge on input {i} ({:#x})",
                input[0]
            );
        }
        // Final popcounts too.
        let last = traces.last().unwrap();
        let expect: Vec<i32> = last.popcounts.iter().map(|&p| p as i32).collect();
        assert_eq!(out.final_popcount[i], expect, "popcount diverges on input {i}");
    }
}

#[test]
fn oracle_rejects_wrong_width() {
    let oracle = Oracle::load(artifacts_dir()).expect("run `make artifacts` first");
    let err = oracle.run(&[vec![1, 2, 3]]).unwrap_err();
    assert!(err.to_string().contains("packed words"));
}
