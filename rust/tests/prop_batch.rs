//! Property tests for the batched SoA executor (ISSUE 1 acceptance):
//!
//! B1. For ANY valid model, trace, and batch size (including batch = 1
//!     and recirculating models), [`BatchedTape`] output is bit-exact
//!     with the scalar [`Pipeline`] — full-PHV equality per lane — and
//!     with the trusted `bnn::forward` reference.
//! B2. Malformed packets are masked per lane (flagged + zeroed) where
//!     the scalar path reports a parse error, without disturbing the
//!     other lanes.
//! B3. The keyed-table (multi-model) path is lane-exact too.

use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::compiler::{
    Compiler, CompilerOptions, InputEncoding, MultiModelOptions,
};
use n2net::rmt::{BatchedTape, ChipConfig, Pipeline};
use n2net::util::prop::{self, pow2_in};
use n2net::util::rng::Rng;

fn frame_for(x: &PackedBits) -> Vec<u8> {
    let mut pkt = Vec::with_capacity(x.words().len() * 4);
    for w in x.words() {
        pkt.extend_from_slice(&w.to_le_bytes());
    }
    pkt
}

/// Random feasible spec, biased small for speed (cf. `prop_pipeline`).
fn random_spec(rng: &mut Rng) -> (usize, Vec<usize>) {
    let in_bits = pow2_in(rng, 16, 256);
    let n_layers = 1 + rng.gen_range(0, 2);
    let mut layers = Vec::new();
    for i in 0..n_layers {
        if i + 1 == n_layers {
            layers.push(1 + rng.gen_range(0, 32));
        } else {
            layers.push(pow2_in(rng, 16, 64));
        }
    }
    (in_bits, layers)
}

/// One random scenario: model + mixed valid/malformed trace + batch
/// size; checks B1 and B2 against the scalar pipeline and reference.
fn check_batch_equivalence(chip: ChipConfig, rng: &mut Rng) -> Result<(), String> {
    let (in_bits, layers) = random_spec(rng);
    let seed = rng.next_u64();
    let model = BnnModel::random(in_bits, &layers, seed);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        weights_as_immediates: rng.gen_bool(0.5),
        ..Default::default()
    };
    let compiled = Compiler::new(chip.clone(), opts)
        .compile(&model)
        .map_err(|e| format!("compile {in_bits}b->{layers:?}: {e}"))?;
    let mut scalar = Pipeline::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .map_err(|e| e.to_string())?;
    let mut tape = BatchedTape::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .map_err(|e| e.to_string())?;

    let batch_size = *[1usize, 2, 7, 33, 64]
        .get(rng.gen_range(0, 5))
        .unwrap();
    let mut inputs: Vec<Option<PackedBits>> = Vec::with_capacity(batch_size);
    let mut packets: Vec<Vec<u8>> = Vec::with_capacity(batch_size);
    for _ in 0..batch_size {
        let x = PackedBits::random(in_bits, rng);
        let mut frame = frame_for(&x);
        // ~1 in 6 packets is truncated (malformed).
        if rng.gen_range(0, 6) == 0 && !frame.is_empty() {
            frame.truncate(rng.gen_range(0, frame.len()));
            inputs.push(None);
        } else {
            inputs.push(Some(x));
        }
        packets.push(frame);
    }

    let batch = tape.process_batch(&packets);
    if batch.n_lanes() != batch_size {
        return Err(format!("lane count {} != {batch_size}", batch.n_lanes()));
    }
    for (l, input) in inputs.iter().enumerate() {
        match input {
            None => {
                // B2: malformed — scalar must also reject, lane masked.
                if batch.lane_ok(l) {
                    return Err(format!("lane {l}: malformed packet not masked"));
                }
                if scalar.process_packet(&packets[l]).is_ok() {
                    return Err(format!("lane {l}: scalar accepted malformed pkt"));
                }
            }
            Some(x) => {
                if !batch.lane_ok(l) {
                    return Err(format!("lane {l}: valid packet masked"));
                }
                let phv = scalar
                    .process_packet(&packets[l])
                    .map_err(|e| format!("lane {l}: scalar: {e}"))?;
                // B1: full-PHV equality with the scalar executor.
                if batch.lane_phv(l, &chip.phv) != phv {
                    return Err(format!(
                        "lane {l}: PHV diverged ({in_bits}b->{layers:?} \
                         seed {seed:#x} batch {batch_size})"
                    ));
                }
                // …and with the reference forward.
                let got = PackedBits::from_words(
                    batch.read_group(l, &compiled.layout.output),
                    compiled.output_bits,
                );
                let expect = bnn::forward(&model, x);
                if got != expect {
                    return Err(format!(
                        "lane {l}: output {got:?} != reference {expect:?} \
                         ({in_bits}b->{layers:?} seed {seed:#x})"
                    ));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn b1_b2_batched_equals_scalar_and_reference_stock_chip() {
    prop::check("batch≡scalar/stock", prop::default_cases(), |rng| {
        check_batch_equivalence(ChipConfig::rmt(), rng)
    });
}

#[test]
fn b1_b2_batched_equals_scalar_and_reference_native_popcnt() {
    prop::check("batch≡scalar/native", prop::default_cases(), |rng| {
        check_batch_equivalence(ChipConfig::rmt_with_popcnt(), rng)
    });
}

#[test]
fn b1_recirculating_model_every_batch_size() {
    // 32b -> [128, 16] needs > 32 elements: multi-round layer 0 plus a
    // second layer, i.e. a genuine recirculation program.
    let chip = ChipConfig::rmt();
    let model = BnnModel::random(32, &[128, 16], 0xBEEF);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        ..Default::default()
    };
    let compiled = Compiler::new(chip.clone(), opts).compile(&model).unwrap();
    assert!(
        compiled.program.n_elements() > chip.n_elements,
        "model must recirculate for this test to bite"
    );
    let mut scalar = Pipeline::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let mut tape = BatchedTape::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let mut rng = Rng::seed_from_u64(99);
    for batch_size in [1usize, 3, 64, 257] {
        let inputs: Vec<PackedBits> =
            (0..batch_size).map(|_| PackedBits::random(32, &mut rng)).collect();
        let packets: Vec<Vec<u8>> = inputs.iter().map(frame_for).collect();
        let batch = tape.process_batch(&packets);
        for (l, x) in inputs.iter().enumerate() {
            let phv = scalar.process_packet(&packets[l]).unwrap();
            assert_eq!(
                batch.lane_phv(l, &chip.phv),
                phv,
                "batch {batch_size} lane {l}"
            );
            assert_eq!(
                PackedBits::from_words(
                    batch.read_group(l, &compiled.layout.output),
                    compiled.output_bits,
                ),
                bnn::forward(&model, x),
                "batch {batch_size} lane {l} vs reference"
            );
        }
    }
}

#[test]
fn b3_multi_model_keyed_tables_lane_exact() {
    // Keyed match stages (per-packet weight selection) take the
    // per-lane fallback inside the SoA executor; outputs must still be
    // lane-exact with the scalar pipeline and each model's reference.
    let models: Vec<(u32, BnnModel)> = vec![
        (7, BnnModel::random(32, &[32, 16], 100)),
        (13, BnnModel::random(32, &[32, 16], 200)),
        (99, BnnModel::random(32, &[32, 16], 300)),
    ];
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 4 },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts)
        .compile_multi(&models, MultiModelOptions { id_offset: 0 })
        .unwrap();
    let chip = ChipConfig::rmt();
    let mut scalar = Pipeline::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let mut tape = BatchedTape::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let frame = |id: u32, x: &PackedBits| -> Vec<u8> {
        let mut pkt = id.to_le_bytes().to_vec();
        for w in x.words() {
            pkt.extend_from_slice(&w.to_le_bytes());
        }
        pkt
    };
    let mut rng = Rng::seed_from_u64(1);
    // Interleave all three model ids in one batch.
    let mut packets = Vec::new();
    let mut expected = Vec::new();
    for _ in 0..10 {
        for (id, model) in &models {
            let x = PackedBits::random(32, &mut rng);
            packets.push(frame(*id, &x));
            expected.push(bnn::forward(model, &x));
        }
    }
    let batch = tape.process_batch(&packets);
    for (l, expect) in expected.iter().enumerate() {
        assert!(batch.lane_ok(l));
        let phv = scalar.process_packet(&packets[l]).unwrap();
        assert_eq!(batch.lane_phv(l, &chip.phv), phv, "lane {l}");
        assert_eq!(
            &PackedBits::from_words(
                batch.read_group(l, &compiled.layout.output),
                compiled.output_bits,
            ),
            expect,
            "lane {l}"
        );
    }
}
