//! Sharded-serving equivalence properties (ISSUE 3 acceptance):
//!
//! S1. For EVERY scenario kind and any shard count, the sharded tier's
//!     outputs are bit-exact with the single-engine outputs over the
//!     same deployment (lossless `Block` policy) — flow-affinity
//!     dispatch, per-shard batching, and queue reordering must never
//!     change a prediction.
//! S2. The same holds for the keyed multi-tenant program under
//!     `multi-tenant-mix` traffic.
//! S3. Under a concurrent hot-swap, every packet of a sharded run is
//!     bit-exact with either the old or the new model, the per-shard
//!     versions stay within the published range (skew is bounded), and
//!     the served version range is monotone across successive runs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use n2net::backend::out_mask;
use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::net::{Scenario, MODEL_ID_OFFSET};
use n2net::util::prop;
use n2net::util::rng::Rng;

/// The scenario pool S1 draws from (multi-tenant-mix is S2's — it needs
/// the keyed registry).
fn scenario_for(rng: &mut Rng) -> Scenario {
    match rng.gen_range(0, 5) {
        0 => Scenario::Uniform,
        1 => Scenario::ZipfHeavyHitter {
            n_flows: 2 + rng.gen_range(0, 64),
            hitter_share: 0.2 + rng.gen_f64() * 0.4,
        },
        2 => Scenario::DdosBurst {
            ddos: Scenario::default_ddos(),
            peak_fraction: 0.5 + rng.gen_f64() * 0.4,
        },
        3 => Scenario::FlowletChurn {
            n_flows: 1 + rng.gen_range(0, 32),
            flowlet_len: 1 + rng.gen_range(0, 48),
        },
        _ => Scenario::MalformedFuzz { malformed_share: rng.gen_f64() },
    }
}

fn check_sharded_matches_engine(rng: &mut Rng) -> Result<(), String> {
    let scenario = scenario_for(rng);
    let n_shards = 1 + rng.gen_range(0, 6);
    let layers = vec![1 + rng.gen_range(0, 24)];
    let model = BnnModel::random(32, &layers, rng.next_u64());
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .workers(2)
        .model("m", model)
        .build()
        .map_err(|e| format!("deploy 32b->{layers:?}: {e}"))?;
    let n = 50 + rng.gen_range(0, 400);
    let trace = scenario.generate(rng.next_u64(), n);

    let engine = deployment
        .serve_trace("m", &trace.packets)
        .map_err(|e| e.to_string())?;
    let sharded = deployment
        .serve_trace_sharded("m", n_shards, &trace.packets)
        .map_err(|e| e.to_string())?;
    if sharded.outputs != engine.outputs {
        let i = sharded
            .outputs
            .iter()
            .zip(&engine.outputs)
            .position(|(a, b)| a != b)
            .unwrap();
        return Err(format!(
            "scenario {} with {n_shards} shards diverged at pkt {i}: \
             sharded {:#x} vs engine {:#x}",
            scenario.name(),
            sharded.outputs[i],
            engine.outputs[i]
        ));
    }
    if sharded.parse_errors != engine.parse_errors {
        return Err(format!(
            "parse-error accounting diverged: sharded {} vs engine {}",
            sharded.parse_errors, engine.parse_errors
        ));
    }
    if sharded.dropped != 0 {
        return Err(format!(
            "Block policy shed {} frames",
            sharded.dropped
        ));
    }
    let delivered: u64 = sharded.per_shard.iter().map(|s| s.packets).sum();
    if delivered != n as u64 {
        return Err(format!("shards delivered {delivered} of {n}"));
    }
    Ok(())
}

#[test]
fn prop_s1_sharded_output_is_bit_exact_under_every_scenario() {
    let cases = prop::default_cases().min(24);
    prop::check("sharded-vs-engine", cases, check_sharded_matches_engine);
}

#[test]
fn s2_keyed_multi_tenant_mix_is_bit_exact_sharded() {
    let a = BnnModel::random(32, &[16], 61);
    let b = BnnModel::random(32, &[16], 62);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .keyed(MODEL_ID_OFFSET)
        .model_with_id("a", 1, a)
        .model_with_id("b", 2, b)
        .build()
        .unwrap();
    let mix = Scenario::MultiTenantMix {
        model_ids: vec![1, 2],
        unknown_share: 0.2,
    }
    .generate(63, 800);
    let engine = deployment.serve_trace_keyed(&mix.packets).unwrap();
    for n_shards in [1usize, 2, 5] {
        let sharded = deployment
            .sharded_engine_keyed(n_shards)
            .unwrap()
            .process_trace(&mix.packets)
            .unwrap();
        assert_eq!(
            sharded.outputs, engine.outputs,
            "keyed sharded ≡ keyed engine at {n_shards} shards"
        );
    }
}

#[test]
fn s3_concurrent_hot_swap_never_tears_and_skew_is_bounded() {
    let model_a = BnnModel::random(32, &[16, 1], 71);
    let model_b = BnnModel::random(32, &[16, 1], 72);
    let deployment = Arc::new(
        Deployment::builder()
            .extractor(FieldExtractor::SrcIp)
            .model("m", model_a.clone())
            .build()
            .unwrap(),
    );
    let trace = Scenario::Uniform.generate(73, 3000);
    let engine = deployment.sharded_engine("m", 4).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let swapper = {
        let deployment = Arc::clone(&deployment);
        let stop = Arc::clone(&stop);
        let (a, b) = (model_a.clone(), model_b.clone());
        std::thread::spawn(move || {
            let mut k = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let next = if k % 2 == 0 { &b } else { &a };
                deployment.swap_model("m", next.clone()).unwrap();
                k += 1;
                std::thread::yield_now();
            }
        })
    };

    let mask = out_mask(1);
    let mut last_version_max = 0u64;
    for run in 0..5 {
        let report = engine.process_trace(&trace.packets).unwrap();
        // Old-or-new per packet: no torn weights, ever.
        for (i, &key) in trace.keys.iter().enumerate() {
            let x = PackedBits::from_u32(key);
            let ea = bnn::forward(&model_a, &x).words().first().copied().unwrap_or(0)
                & mask;
            let eb = bnn::forward(&model_b, &x).words().first().copied().unwrap_or(0)
                & mask;
            let got = report.outputs[i];
            assert!(
                got == ea || got == eb,
                "run {run} pkt {i}: got {got}, model A says {ea}, model B says {eb}"
            );
        }
        // Version skew across shards is bounded by what was published,
        // and monotone per shard across runs (the engine reuses the
        // same slot; a later run can never serve an older version).
        assert!(report.version_min >= 1);
        assert!(report.version_min <= report.version_max);
        assert!(
            report.version_max <= deployment.version("m").unwrap(),
            "shard served a version that was never published"
        );
        assert!(
            report.version_max >= last_version_max,
            "served version range went backwards across runs"
        );
        last_version_max = report.version_max;
        for st in &report.per_shard {
            assert!(
                st.model_version >= report.version_min
                    && st.model_version <= report.version_max
            );
        }
    }
    stop.store(true, Ordering::Relaxed);
    swapper.join().unwrap();
}
