//! CLI smoke tests (ISSUE 4 satellite): the serving subcommands must
//! teach their scenario vocabulary — `--help` lists every name, and a
//! typo'd `--scenario` enumerates them — and a tiny `autopilot` run
//! must complete end to end without trained artifacts.

use std::process::Command;

use n2net::net::SCENARIO_NAMES;

fn n2net(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_n2net"))
        .args(args)
        .output()
        .expect("spawn n2net")
}

#[test]
fn serve_help_lists_every_scenario_name() {
    let out = n2net(&["serve", "--help"]);
    assert!(out.status.success(), "serve --help failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in SCENARIO_NAMES {
        assert!(stdout.contains(name), "serve --help missing {name:?}:\n{stdout}");
    }
    assert!(stdout.contains("--adaptive"), "{stdout}");
    assert!(stdout.contains("--policy"), "{stdout}");
}

#[test]
fn autopilot_help_lists_every_scenario_name() {
    let out = n2net(&["autopilot", "--help"]);
    assert!(out.status.success(), "autopilot --help failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in SCENARIO_NAMES {
        assert!(
            stdout.contains(name),
            "autopilot --help missing {name:?}:\n{stdout}"
        );
    }
    assert!(stdout.contains("--sequence"), "{stdout}");
}

#[test]
fn timing_help_documents_the_knobs() {
    let out = n2net(&["timing", "--help"]);
    assert!(out.status.success(), "timing --help failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for flag in ["--in-bits", "--layers", "--native-popcnt", "--seed", "--packets"] {
        assert!(stdout.contains(flag), "timing --help missing {flag}:\n{stdout}");
    }
    assert!(stdout.contains("cycle-accurate"), "{stdout}");
}

#[test]
fn check_help_documents_the_knobs() {
    let out = n2net(&["check", "--help"]);
    assert!(out.status.success(), "check --help failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for flag in
        ["--in-bits", "--layers", "--deny-warnings", "--prefix-classifier"]
    {
        assert!(stdout.contains(flag), "check --help missing {flag}:\n{stdout}");
    }
    assert!(stdout.contains("static verification"), "{stdout}");
}

#[test]
fn check_passes_cleanly_on_compiler_output() {
    // ISSUE 8 acceptance (CI verify-smoke shape): `check
    // --deny-warnings` over an honestly-compiled model must exit 0 with
    // a clean report — the compiler's own output carries zero
    // violations, warnings included.
    for extra in [&[][..], &["--native-popcnt"][..], &["--prefix-classifier"][..]]
    {
        let mut args = vec!["check", "--deny-warnings", "--seed", "2"];
        args.extend_from_slice(extra);
        let out = n2net(&args);
        let stdout = String::from_utf8_lossy(&out.stdout);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "check {extra:?} failed:\n{stdout}\n{stderr}");
        assert!(
            stdout.contains("verify: clean"),
            "check {extra:?} not clean:\n{stdout}"
        );
    }
}

#[test]
fn timing_run_prints_stage_table_width_scaling_and_host_comparison() {
    // ISSUE 7 acceptance: a hermetic `timing` run (synthetic weights,
    // no artifacts) prints the per-stage cycle/occupancy table, the
    // modeled pps row for every Table 1 activation width, and the
    // modeled-vs-host comparison.
    let out = n2net(&["timing", "--packets", "2048", "--seed", "9"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "timing run failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("chip timing: clock 960 MHz"), "{stdout}");
    // Per-stage table: header plus the totals line.
    for col in ["pass", "stage", "occ%", "cycles/packet"] {
        assert!(stdout.contains(col), "stage table missing {col:?}:\n{stdout}");
    }
    // Width table covers all of Table 1's activation widths.
    for width in ["16", "32", "64", "128", "256", "512", "1024", "2048"] {
        assert!(stdout.contains(width), "width row {width} missing:\n{stdout}");
    }
    // Host comparison ran over the requested trace.
    assert!(stdout.contains("modeled vs host (2048 packets"), "{stdout}");
    for backend in ["scalar", "batched", "specialized"] {
        assert!(stdout.contains(backend), "comparison missing {backend}:\n{stdout}");
    }
    assert!(stdout.contains("ASIC/host"), "{stdout}");
}

#[test]
fn unknown_scenario_error_enumerates_the_vocabulary() {
    let out = n2net(&["serve", "--scenario", "warp-speed", "--packets", "16"]);
    assert!(!out.status.success(), "bogus scenario must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    for name in SCENARIO_NAMES {
        assert!(stderr.contains(name), "error missing {name:?}:\n{stderr}");
    }
}

#[test]
fn live_adaptive_serve_reacts_to_a_burst_with_zero_quiet_actions() {
    // ISSUE 5 acceptance: `serve --adaptive --live` on a
    // ddos-burst,uniform sequence reacts (swap or reshard) within a
    // bounded number of windows and takes NO action on the quiet
    // segment. The uniform tail spans 8 windows — well past the
    // 2-window attack-attribution slack — so the quiet-actions
    // assertion is falsifiable (a tail shorter than the slack would
    // attribute every window to the attack and the check would be
    // vacuous). Hermetic: the crafted subnet classifier serves.
    let out = n2net(&[
        "serve",
        "--adaptive",
        "--live",
        "--sequence",
        "ddos-burst:2048,uniform:2048",
        "--window",
        "256",
        "--shards",
        "2",
        "--seed",
        "5",
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "live serve failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("sequence: ddos-burst:2048,uniform:2048"), "{stdout}");
    // Match the EVENT render (`published "attack" as v2` / `resharded
    // tier to N shard(s)`), not the always-printed `published=N`
    // summary counter — the latter would make this assertion vacuous.
    assert!(
        stdout.contains("published \"") || stdout.contains("resharded tier"),
        "the loop must react to the burst:\n{stdout}"
    );
    assert!(
        !stdout.contains("published=0 reconfigs=0"),
        "the summary must record the reaction:\n{stdout}"
    );
    assert!(
        stdout.contains("quiet-segment actions: 0"),
        "no actions on quiet traffic:\n{stdout}"
    );
    assert!(stdout.contains("live loop:"), "{stdout}");
    assert!(stdout.contains("live stream:"), "{stdout}");
}

#[test]
fn obs_help_documents_the_surfaces() {
    let out = n2net(&["obs", "--help"]);
    assert!(out.status.success(), "obs --help failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for word in ["expose", "dump", "spans", "--trace", "--metrics-file", "--sequence"]
    {
        assert!(stdout.contains(word), "obs --help missing {word:?}:\n{stdout}");
    }
}

#[test]
fn obs_spans_renders_the_causal_chain_hermetically() {
    // ISSUE 9 acceptance (CLI shape): a hermetic `obs` run whose
    // ddos-ramp detector fires renders the causal chain — window →
    // detection → rule → action → outcome — with a flight dump.
    let out = n2net(&[
        "obs",
        "spans",
        "--sequence",
        "uniform:1024,ddos-burst:2048,uniform:512",
        "--window",
        "256",
        "--shards",
        "2",
        "--seed",
        "3",
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "obs spans failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("observed run:"), "{stdout}");
    for part in [
        "window signal window w",
        "flight-dump",
        "detection ddos-ramp",
        "rule 0: on ddos-ramp do swap attack",
        "action swap attack",
        "outcome published \"attack\"",
    ] {
        assert!(stdout.contains(part), "span tree missing {part:?}:\n{stdout}");
    }
}

#[test]
fn obs_expose_and_serve_metrics_file_share_the_registry_format() {
    // `obs expose` prints the Prometheus exposition; `serve
    // --metrics-file` writes the same registry surface to a file.
    let out = n2net(&[
        "obs",
        "expose",
        "--sequence",
        "uniform:512",
        "--window",
        "256",
        "--seed",
        "3",
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "obs expose failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("# TYPE tier_engine_packets_in counter"), "{stdout}");
    assert!(stdout.contains("tier_n_shards 2"), "{stdout}");
    assert!(stdout.contains("deploy_model_live_version 1"), "{stdout}");

    let dir = std::env::temp_dir().join(format!(
        "n2net-cli-smoke-{}-metrics.prom",
        std::process::id()
    ));
    let path = dir.to_string_lossy().into_owned();
    let out = n2net(&[
        "serve",
        "--packets",
        "512",
        "--shards",
        "2",
        "--seed",
        "3",
        "--metrics-file",
        &path,
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "serve --metrics-file failed:\n{stdout}\n{stderr}");
    let exposed = std::fs::read_to_string(&path).expect("metrics file written");
    std::fs::remove_file(&path).ok();
    assert!(exposed.contains("# TYPE tier_engine_packets_in counter"), "{exposed}");
    assert!(exposed.contains("tier_engine_packets_in 512"), "{exposed}");
    assert!(exposed.contains("# TYPE deploy_model_serve_version gauge"), "{exposed}");
}

fn policy_path(rel: &str) -> String {
    format!("{}/../examples/policies/{rel}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn lint_help_documents_the_analyses_and_knobs() {
    let out = n2net(&["lint", "--help"]);
    assert!(out.status.success(), "lint --help failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for flag in
        ["--policy", "--deny-warnings", "--keyed", "--modeled-slo", "--slo-limit-ns"]
    {
        assert!(stdout.contains(flag), "lint --help missing {flag}:\n{stdout}");
    }
    for code in ["swap-cycle", "shadowed-rule", "unreachable-rule", "slo-always-fires"]
    {
        assert!(stdout.contains(code), "lint --help missing {code:?}:\n{stdout}");
    }
    assert!(stdout.contains("static policy verification"), "{stdout}");
}

#[test]
fn lint_passes_the_builtin_default_and_the_good_corpus() {
    // ISSUE 10 acceptance: every shipped example policy AND the
    // built-in default pass `lint --deny-warnings`. Hermetic: the
    // crafted subnet classifier stands in for trained weights.
    let mut runs: Vec<Vec<String>> = vec![vec![]]; // no --policy = built-in
    for name in ["good/default.policy", "good/escalation.policy", "good/recovery.policy"]
    {
        runs.push(vec!["--policy".into(), policy_path(name)]);
    }
    for extra in runs {
        let mut args: Vec<String> = vec![
            "lint".into(),
            "--deny-warnings".into(),
            "--artifacts".into(),
            "/nonexistent-n2net-artifacts".into(),
        ];
        args.extend(extra.iter().cloned());
        let argv: Vec<&str> = args.iter().map(String::as_str).collect();
        let out = n2net(&argv);
        let stdout = String::from_utf8_lossy(&out.stdout);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "lint {extra:?} failed:\n{stdout}\n{stderr}");
        assert!(stdout.contains("lint: clean"), "lint {extra:?}:\n{stdout}");
    }
}

#[test]
fn lint_rejects_an_oscillating_policy_with_the_diagnostic_on_stderr() {
    let path = policy_path("bad/oscillate.policy");
    let out = n2net(&[
        "lint",
        "--policy",
        &path,
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    assert!(!out.status.success(), "oscillating policy must fail lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("error[swap-cycle]"), "{stdout}");
    assert!(
        stderr.contains("swap-cycle"),
        "the diagnostic must reach stderr:\n{stderr}"
    );
}

#[test]
fn lint_deny_warnings_flips_a_warning_only_run_to_failure() {
    let path = policy_path("bad/shadowed.policy");
    let base = [
        "lint",
        "--policy",
        path.as_str(),
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ];
    let out = n2net(&base);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "warning-only policy passes plain lint:\n{stdout}"
    );
    assert!(stdout.contains("warning[shadowed-rule]"), "{stdout}");

    let mut deny = base.to_vec();
    deny.push("--deny-warnings");
    let out = n2net(&deny);
    assert!(!out.status.success(), "--deny-warnings must flip it to failure");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("shadowed-rule"), "{stderr}");
    assert!(stderr.contains("warnings denied"), "{stderr}");
}

#[test]
fn lint_modeled_slo_judges_thresholds_against_the_cycle_model() {
    // A 1 ns limit sits below any program's drain floor: always-fires,
    // an error even without --deny-warnings.
    let out = n2net(&[
        "lint",
        "--modeled-slo",
        "--slo-limit-ns",
        "1",
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    assert!(!out.status.success(), "sub-floor SLO limit must fail lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("error[slo-always-fires]"), "{stdout}");
    assert!(stderr.contains("slo-always-fires"), "{stderr}");

    // A 1-second limit exceeds any reachable queue's drain: the rule is
    // dead — a warning that only --deny-warnings escalates.
    let base = [
        "lint",
        "--modeled-slo",
        "--slo-limit-ns",
        "999999999",
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ];
    let out = n2net(&base);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "never-fires is advisory:\n{stdout}");
    assert!(stdout.contains("warning[slo-never-fires]"), "{stdout}");
    let mut deny = base.to_vec();
    deny.push("--deny-warnings");
    let out = n2net(&deny);
    assert!(!out.status.success(), "--deny-warnings escalates slo-never-fires");
}

#[test]
fn serve_adaptive_refuses_an_oscillating_policy_before_the_loop_spawns() {
    // ISSUE 10 acceptance: the pre-flight gate refuses error-severity
    // findings BEFORE the live controller thread (or tier) exists — no
    // window is ever served under an oscillating policy.
    let path = policy_path("bad/oscillate.policy");
    let out = n2net(&[
        "serve",
        "--adaptive",
        "--live",
        "--sequence",
        "uniform:256",
        "--window",
        "128",
        "--shards",
        "2",
        "--seed",
        "5",
        "--policy",
        &path,
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "oscillating policy must be refused");
    assert!(stdout.contains("error[swap-cycle]"), "{stdout}");
    assert!(stderr.contains("policy refused by pre-flight lint"), "{stderr}");
    assert!(
        !stdout.contains("live loop:") && !stdout.contains("live stream:"),
        "refusal must land before serving starts:\n{stdout}"
    );
}

#[test]
fn tiny_autopilot_run_completes_without_artifacts() {
    // --artifacts pointing nowhere forces the crafted subnet
    // classifier, so this runs hermetically (and fast: ~1.5k frames).
    let out = n2net(&[
        "autopilot",
        "--sequence",
        "uniform:256,ddos-burst:1024,uniform:256",
        "--window",
        "128",
        "--shards",
        "2",
        "--seed",
        "3",
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "autopilot failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("sequence: uniform:256,ddos-burst:1024,uniform:256"));
    assert!(stdout.contains("closed-loop sim"), "{stdout}");
    assert!(stdout.contains("policy:"), "{stdout}");
}
