//! CLI smoke tests (ISSUE 4 satellite): the serving subcommands must
//! teach their scenario vocabulary — `--help` lists every name, and a
//! typo'd `--scenario` enumerates them — and a tiny `autopilot` run
//! must complete end to end without trained artifacts.

use std::process::Command;

use n2net::net::SCENARIO_NAMES;

fn n2net(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_n2net"))
        .args(args)
        .output()
        .expect("spawn n2net")
}

#[test]
fn serve_help_lists_every_scenario_name() {
    let out = n2net(&["serve", "--help"]);
    assert!(out.status.success(), "serve --help failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in SCENARIO_NAMES {
        assert!(stdout.contains(name), "serve --help missing {name:?}:\n{stdout}");
    }
    assert!(stdout.contains("--adaptive"), "{stdout}");
    assert!(stdout.contains("--policy"), "{stdout}");
}

#[test]
fn autopilot_help_lists_every_scenario_name() {
    let out = n2net(&["autopilot", "--help"]);
    assert!(out.status.success(), "autopilot --help failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in SCENARIO_NAMES {
        assert!(
            stdout.contains(name),
            "autopilot --help missing {name:?}:\n{stdout}"
        );
    }
    assert!(stdout.contains("--sequence"), "{stdout}");
}

#[test]
fn unknown_scenario_error_enumerates_the_vocabulary() {
    let out = n2net(&["serve", "--scenario", "warp-speed", "--packets", "16"]);
    assert!(!out.status.success(), "bogus scenario must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    for name in SCENARIO_NAMES {
        assert!(stderr.contains(name), "error missing {name:?}:\n{stderr}");
    }
}

#[test]
fn tiny_autopilot_run_completes_without_artifacts() {
    // --artifacts pointing nowhere forces the crafted subnet
    // classifier, so this runs hermetically (and fast: ~1.5k frames).
    let out = n2net(&[
        "autopilot",
        "--sequence",
        "uniform:256,ddos-burst:1024,uniform:256",
        "--window",
        "128",
        "--shards",
        "2",
        "--seed",
        "3",
        "--artifacts",
        "/nonexistent-n2net-artifacts",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "autopilot failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("sequence: uniform:256,ddos-burst:1024,uniform:256"));
    assert!(stdout.contains("closed-loop sim"), "{stdout}");
    assert!(stdout.contains("policy:"), "{stdout}");
}
