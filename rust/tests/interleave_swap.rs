//! Deterministic-interleaving checks for the publication protocols
//! (ISSUE 8 satellite). `tests/prop_hotswap.rs` samples real-thread
//! schedules; this file *enumerates* every schedule of small abstract
//! models instead — a loom-style exhaustive explorer built on plain
//! DFS, std-only.
//!
//! Two protocols are modeled:
//!
//! * [`deploy::SwapCell`] — writer: lock, replace the `Arc`, bump the
//!   version WHILE the lock is held, unlock; reader: lock, read the
//!   pointer and the version together, unlock. The checked invariant
//!   is pair consistency (every observed `(value, version)` was
//!   published together) plus per-reader version monotonicity.
//! * the shard `TierCell` generation handshake
//!   (`coordinator/shard.rs`): reshard stores the new shard count
//!   FIRST and bumps the generation SECOND (Release), so a dispatcher
//!   that observes the bumped generation (Acquire) must observe the
//!   new count. Under sequentially-consistent enumeration that
//!   publish-then-bump ordering is exactly what the invariant checks.
//!
//! The explorer's teeth are demonstrated, not assumed: for each
//! protocol a deliberately broken variant (bump outside the lock /
//! bump before the store / version peeked outside the critical
//! section) must be CAUGHT by some schedule. The model is validated
//! against the real `SwapCell` sequentially.

use std::sync::Arc;

use n2net::deploy::SwapCell;

/// One atomic micro-step of a modeled thread. `Lock`/`Unlock` model a
/// mutex (a thread whose next step is `Lock` is blocked while another
/// holds it); the rest touch the two shared words. `Record` snapshots
/// the thread's locally-seen `(value, version)` pair as one
/// observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Step {
    Lock,
    Unlock,
    StoreValue(u64),
    BumpVersion,
    LoadValue,
    LoadVersion,
    Record,
}

#[derive(Clone)]
struct Thread {
    pc: usize,
    seen_value: u64,
    seen_version: u64,
    obs: Vec<(u64, u64)>,
}

#[derive(Clone)]
struct State {
    lock: Option<usize>,
    value: u64,
    version: u64,
    threads: Vec<Thread>,
}

/// Exhaustively explore every interleaving of `programs` from the
/// given initial shared state, invoking `check` on the per-thread
/// observation lists at every terminal state. Returns
/// `(schedules, failures, first_failure)`.
fn explore(
    programs: &[&[Step]],
    value0: u64,
    version0: u64,
    check: &dyn Fn(&[Vec<(u64, u64)>]) -> Result<(), String>,
) -> (usize, usize, Option<String>) {
    let init = State {
        lock: None,
        value: value0,
        version: version0,
        threads: programs
            .iter()
            .map(|_| Thread { pc: 0, seen_value: 0, seen_version: 0, obs: Vec::new() })
            .collect(),
    };
    let mut schedules = 0usize;
    let mut failures = 0usize;
    let mut first = None;
    let mut stack = vec![init];
    while let Some(state) = stack.pop() {
        let mut terminal = true;
        for (ti, program) in programs.iter().enumerate() {
            let t = &state.threads[ti];
            let Some(&step) = program.get(t.pc) else { continue };
            // Lock blocks while held by another thread; everything
            // else (one shared-word access) is always enabled.
            if step == Step::Lock && state.lock.is_some() {
                terminal = false; // runnable later, not a terminal state
                continue;
            }
            terminal = false;
            let mut next = state.clone();
            {
                let t = &mut next.threads[ti];
                t.pc += 1;
                match step {
                    Step::Lock => next.lock = Some(ti),
                    Step::Unlock => {
                        assert_eq!(next.lock, Some(ti), "unlock by non-owner");
                        next.lock = None;
                    }
                    Step::StoreValue(v) => next.value = v,
                    Step::BumpVersion => next.version += 1,
                    Step::LoadValue => t.seen_value = next.value,
                    Step::LoadVersion => t.seen_version = next.version,
                    Step::Record => t.obs.push((t.seen_value, t.seen_version)),
                }
            }
            stack.push(next);
        }
        if terminal {
            // All threads done (a held lock with everyone blocked would
            // be a deadlock — impossible with well-bracketed programs).
            assert!(state.threads.iter().enumerate().all(|(i, t)| t.pc == programs[i].len()));
            schedules += 1;
            let obs: Vec<Vec<(u64, u64)>> =
                state.threads.iter().map(|t| t.obs.clone()).collect();
            if let Err(msg) = check(&obs) {
                failures += 1;
                if first.is_none() {
                    first = Some(msg);
                }
            }
        }
    }
    (schedules, failures, first)
}

// ---------------------------------------------------------------------------
// SwapCell: version bumped while the pointer lock is held
// ---------------------------------------------------------------------------

/// Writer publishing values 1..=n, modeled after `SwapCell::store`:
/// the bump happens INSIDE the critical section.
fn correct_writer(n: u64) -> Vec<Step> {
    let mut p = Vec::new();
    for v in 1..=n {
        p.extend([Step::Lock, Step::StoreValue(v), Step::BumpVersion, Step::Unlock]);
    }
    p
}

/// Reader performing `loads` consistent-pair loads, modeled after
/// `SwapCell::load`: value and version are read under one lock hold.
fn correct_reader(loads: usize) -> Vec<Step> {
    let mut p = Vec::new();
    for _ in 0..loads {
        p.extend([
            Step::Lock,
            Step::LoadValue,
            Step::LoadVersion,
            Step::Record,
            Step::Unlock,
        ]);
    }
    p
}

/// SwapCell invariant: value `v` is published together with version
/// `1 + v` (the cell starts at `(0, 1)`), so every observation must
/// satisfy `version == 1 + value`, and versions are monotone per
/// reader.
fn swapcell_invariant(obs: &[Vec<(u64, u64)>]) -> Result<(), String> {
    for (ti, thread) in obs.iter().enumerate() {
        let mut last = 0;
        for &(v, ver) in thread {
            if ver != 1 + v {
                return Err(format!(
                    "thread {ti} observed torn pair (value {v}, version {ver})"
                ));
            }
            if ver < last {
                return Err(format!("thread {ti}: version went backwards"));
            }
            last = ver;
        }
    }
    Ok(())
}

#[test]
fn swapcell_protocol_is_consistent_under_every_interleaving() {
    let writer = correct_writer(2);
    let r1 = correct_reader(2);
    let r2 = correct_reader(1);
    let (schedules, failures, first) = explore(
        &[&writer, &r1, &r2],
        0,
        1,
        &swapcell_invariant,
    );
    // Every step of the correct protocol is inside a critical section,
    // so the lock serializes the 5 sections and the schedules are
    // exactly their interleavings: 5!/(2!·2!·1!) = 30. Pinning the
    // count proves the explorer's blocking semantics — an explorer
    // that let threads run through a held lock would count more.
    assert_eq!(schedules, 30, "lock-serialized schedule count");
    assert_eq!(failures, 0, "schedules: {schedules}, first: {first:?}");
}

#[test]
fn bump_outside_the_lock_is_caught() {
    // The broken variant prop_hotswap could only hope to sample: the
    // writer unlocks BEFORE bumping, so a reader squeezing into the
    // gap observes (new value, old version).
    let writer = vec![Step::Lock, Step::StoreValue(1), Step::Unlock, Step::BumpVersion];
    let reader = correct_reader(1);
    let (schedules, failures, first) =
        explore(&[&writer, &reader], 0, 1, &swapcell_invariant);
    assert!(failures > 0, "broken writer must be caught ({schedules} schedules)");
    assert!(first.unwrap().contains("torn pair"));
}

#[test]
fn version_peek_outside_the_critical_section_is_caught() {
    // A reader that pairs a lock-free version peek with a locked value
    // read (instead of loading both under the lock) can tear.
    let writer = correct_writer(1);
    let reader = vec![
        Step::LoadVersion, // peeked too early
        Step::Lock,
        Step::LoadValue,
        Step::Record,
        Step::Unlock,
    ];
    let (schedules, failures, first) =
        explore(&[&writer, &reader], 0, 1, &swapcell_invariant);
    assert!(failures > 0, "broken reader must be caught ({schedules} schedules)");
    assert!(first.unwrap().contains("torn pair"));
}

#[test]
fn model_matches_the_real_swapcell_sequentially() {
    // The abstract model's value<->version mapping is the real cell's:
    // store i is version 1 + i, and load returns the matching pair.
    let cell = SwapCell::new(Arc::new(0u32));
    for i in 1..=5u32 {
        assert_eq!(cell.store(Arc::new(i)), 1 + u64::from(i));
        let (v, ver) = cell.load();
        assert_eq!((*v, ver), (i, 1 + u64::from(i)));
        assert_eq!(cell.version(), ver);
    }
}

// ---------------------------------------------------------------------------
// TierCell: store the shard count BEFORE bumping the generation
// ---------------------------------------------------------------------------

/// The dispatcher's handshake: observe the generation, then read the
/// shard count, then record the pair `(count, generation)`. (The real
/// dispatcher drains and rebuilds between the two reads; any extra
/// delay only widens the window the explorer already covers.)
const TIER_READER: &[Step] = &[Step::LoadVersion, Step::LoadValue, Step::Record];

/// TierCell invariant: a reader that observed the bumped generation
/// must observe the resharded count — the Release(bump)/Acquire(read)
/// pairing in `coordinator/shard.rs`.
fn tiercell_invariant(obs: &[Vec<(u64, u64)>]) -> Result<(), String> {
    for (ti, thread) in obs.iter().enumerate() {
        for &(n, generation) in thread {
            if generation >= 1 && n != 2 {
                return Err(format!(
                    "thread {ti} saw generation {generation} with stale shard count {n}"
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn reshard_store_then_bump_is_consistent_under_every_interleaving() {
    // reshard(): n_shards = 2, THEN generation += 1 (1 -> 2 shards).
    let writer = [Step::StoreValue(2), Step::BumpVersion];
    let (schedules, failures, first) = explore(
        &[&writer, TIER_READER, TIER_READER],
        1,
        0,
        &tiercell_invariant,
    );
    // No locks here — the atomics interleave freely: 8!/(2!·3!·3!)
    // = 560 schedules, all of them explored.
    assert_eq!(schedules, 560, "free-interleaving schedule count");
    assert_eq!(failures, 0, "schedules: {schedules}, first: {first:?}");
}

#[test]
fn reshard_bump_before_store_is_caught() {
    let writer = [Step::BumpVersion, Step::StoreValue(2)];
    let (schedules, failures, first) =
        explore(&[&writer, TIER_READER], 1, 0, &tiercell_invariant);
    assert!(failures > 0, "bump-first reshard must be caught ({schedules} schedules)");
    assert!(first.unwrap().contains("stale shard count"));
}
