//! E1 — Table 1 reproduction, asserted two independent ways:
//! (a) the closed-form accounting (`compiler::table1`) against the
//!     paper's literal numbers;
//! (b) recounting elements from actually-emitted programs.

use n2net::bnn::BnnModel;
use n2net::compiler::{table1, Compiler, CompilerOptions, InputEncoding};
use n2net::rmt::ChipConfig;

const PAPER_TABLE1: [(usize, usize, usize); 8] = [
    (16, 128, 12),
    (32, 64, 14),
    (64, 32, 16),
    (128, 16, 18),
    (256, 8, 20),
    (512, 4, 22),
    (1024, 2, 24),
    (2048, 1, 25),
];

#[test]
fn closed_form_matches_paper() {
    let rows = table1(&ChipConfig::rmt());
    for (row, (n, p, e)) in rows.iter().zip(PAPER_TABLE1) {
        assert_eq!(row.activation_bits, n);
        assert_eq!(row.parallel_neurons, p, "N={n}: parallel neurons");
        assert_eq!(row.elements, e, "N={n}: elements");
    }
}

#[test]
fn emitted_programs_match_paper_counts() {
    // Compile a maximal single-round group for each width and count the
    // actual elements in the emitted program. (For N=16 the paper's 128
    // bit-capacity parallel neurons assume the RMT PHV's 16-bit
    // containers; on the uniform-32b model a single round holds 64 —
    // the per-group *element count*, which is what Table 1's third row
    // states, is identical. See DESIGN.md §Hardware-Adaptation.)
    for (n, p, e) in PAPER_TABLE1 {
        let p = if n == 16 { 64 } else { p };
        let model = BnnModel::random(n, &[p], n as u64);
        let opts = CompilerOptions {
            input: InputEncoding::PayloadLe { offset: 0 },
            ..Default::default()
        };
        let compiled = Compiler::new(ChipConfig::rmt(), opts)
            .compile(&model)
            .unwrap_or_else(|err| panic!("N={n}: {err}"));
        assert_eq!(
            compiled.program.n_elements(),
            e,
            "N={n}: emitted element count"
        );
        // Single pass — Table 1 configurations all fit the 32 elements.
        assert_eq!(compiled.resources.passes, 1, "N={n}");
        // The paper's claim that a full parallel group fits the op
        // budget: peak ops ≤ 224.
        assert!(
            compiled.resources.peak_ops <= 224,
            "N={n}: peak ops {}",
            compiled.resources.peak_ops
        );
    }
}

#[test]
fn full_16bit_capacity_spills_to_two_rounds_and_stays_correct() {
    // 128 parallel 16-bit neurons (Table 1's bit-capacity) need 256
    // uniform-32b containers, so the compiler runs two rounds of 64 —
    // and the result is still bit-exact.
    let model = BnnModel::random(16, &[128], 99);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts).compile(&model).unwrap();
    let plan = &compiled.layout.layers[0];
    assert!(plan.rounds >= 2, "expected container-driven multi-round");
    assert!(plan.parallel <= 64);
    let mut pipe = n2net::rmt::Pipeline::new(
        ChipConfig::rmt(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let mut rng = n2net::util::rng::Rng::seed_from_u64(5);
    for _ in 0..10 {
        let x = n2net::bnn::PackedBits::random(16, &mut rng);
        let mut pkt = Vec::new();
        for w in x.words() {
            pkt.extend_from_slice(&w.to_le_bytes());
        }
        let phv = pipe.process_packet(&pkt).unwrap();
        assert_eq!(compiled.read_output(&phv), n2net::bnn::forward(&model, &x));
    }
}

#[test]
fn native_popcnt_range_is_5_to_10() {
    // §3: "would change the 12-25 elements range of Table 1 to a 5-10
    // range" and "immediately doubling ... the neurons executed in
    // parallel".
    let stock = table1(&ChipConfig::rmt());
    let native = table1(&ChipConfig::rmt_with_popcnt());
    assert_eq!(native[0].elements, 5);
    assert_eq!(native[7].elements, 10);
    for (s, n) in stock.iter().zip(&native) {
        assert_eq!(n.parallel_neurons, 2 * s.parallel_neurons);
        assert!(n.elements < s.elements);
    }
}
