//! Multi-model extension: several same-architecture BNNs installed in
//! ONE pipeline program, with per-packet weight selection via a
//! model-id header field matched in the XNOR elements' tables — the
//! natural use of the match stage's SRAM ("the values in the PHV are
//! used to perform table lookups and retrieve the instruction the
//! processors should apply", paper §2).

use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::compiler::{
    Compiler, CompilerOptions, InputEncoding, MultiModelOptions,
};
use n2net::rmt::{ChipConfig, Pipeline};
use n2net::util::rng::Rng;

/// Packet: [model_id LE u32][activation words LE].
fn frame(id: u32, x: &PackedBits) -> Vec<u8> {
    let mut pkt = id.to_le_bytes().to_vec();
    for w in x.words() {
        pkt.extend_from_slice(&w.to_le_bytes());
    }
    pkt
}

fn compile_three() -> (Vec<(u32, BnnModel)>, n2net::compiler::CompiledModel) {
    let models: Vec<(u32, BnnModel)> = vec![
        (7, BnnModel::random(32, &[32, 16], 100)),
        (13, BnnModel::random(32, &[32, 16], 200)),
        (99, BnnModel::random(32, &[32, 16], 300)),
    ];
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 4 },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts)
        .compile_multi(&models, MultiModelOptions { id_offset: 0 })
        .unwrap();
    (models, compiled)
}

#[test]
fn per_packet_model_selection_is_bit_exact() {
    let (models, compiled) = compile_three();
    let mut pipe = Pipeline::new(
        ChipConfig::rmt(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let mut rng = Rng::seed_from_u64(1);
    for _ in 0..30 {
        let x = PackedBits::random(32, &mut rng);
        for (id, model) in &models {
            let phv = pipe.process_packet(&frame(*id, &x)).unwrap();
            let got = compiled.read_output(&phv);
            let expect = bnn::forward(model, &x);
            assert_eq!(got, expect, "model {id}, input {x:?}");
        }
    }
}

#[test]
fn unknown_id_falls_back_to_default_model() {
    let (models, compiled) = compile_three();
    let mut pipe = Pipeline::new(
        ChipConfig::rmt(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let mut rng = Rng::seed_from_u64(2);
    let x = PackedBits::random(32, &mut rng);
    let phv = pipe.process_packet(&frame(0xFFFF_FFFF, &x)).unwrap();
    // Miss -> default action data = the first model's weights.
    assert_eq!(compiled.read_output(&phv), bnn::forward(&models[0].1, &x));
}

#[test]
fn weight_tables_consume_sram_per_model() {
    let (_models, compiled) = compile_three();
    // Single-model compile of the same architecture for comparison.
    let single = Compiler::new(
        ChipConfig::rmt(),
        CompilerOptions {
            input: InputEncoding::PayloadLe { offset: 4 },
            ..Default::default()
        },
    )
    .compile(&BnnModel::random(32, &[32, 16], 100))
    .unwrap();
    assert!(
        compiled.resources.sram_bits > 2 * single.resources.sram_bits,
        "3 models must cost more table SRAM than 1: {} vs {}",
        compiled.resources.sram_bits,
        single.resources.sram_bits
    );
    // Same element count — model count costs SRAM, not pipeline stages.
    assert_eq!(
        compiled.program.n_elements(),
        single.program.n_elements()
    );
}

#[test]
fn mismatched_architectures_rejected() {
    let models = vec![
        (1u32, BnnModel::random(32, &[32, 16], 1)),
        (2u32, BnnModel::random(32, &[16, 16], 2)),
    ];
    let err = Compiler::new(ChipConfig::rmt(), CompilerOptions::default())
        .compile_multi(&models, MultiModelOptions { id_offset: 0 });
    assert!(err.is_err());
}

#[test]
fn layout_never_touches_the_id_container() {
    let (_models, compiled) = compile_three();
    let id_slot = ChipConfig::rmt().phv.containers32().last().unwrap().0;
    for e in &compiled.program.elements {
        for op in &e.ops {
            assert_ne!(op.dst().0, id_slot, "element {:?} writes the id", e.label);
        }
    }
}
