//! Hot-swap consistency properties (ISSUE 2 acceptance):
//!
//! H1. Under concurrent `classify_batch` and `swap_model`, EVERY
//!     packet's prediction is bit-exact with either the old or the new
//!     model — no torn reads, no blended weights — and the version
//!     counter observed by the session is monotone.
//! H2. The same holds for the multi-worker engine path, whose workers
//!     re-check the publication version per batch.
//! H3. A failed swap (architecture mismatch) publishes nothing: the old
//!     model keeps serving and the version counter does not move.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use n2net::backend::{out_mask, BackendKind};
use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::util::prop::{self, pow2_in};
use n2net::util::rng::Rng;

/// Raw little-endian activation frame (PayloadAt { offset: 0 }).
fn frame_for(x: &PackedBits) -> Vec<u8> {
    let mut pkt = Vec::with_capacity(x.words().len() * 4);
    for w in x.words() {
        pkt.extend_from_slice(&w.to_le_bytes());
    }
    pkt
}

/// Expected output word of `model` on `x` under the backend trait's
/// low-output-bits convention.
fn expect_word(model: &BnnModel, x: &PackedBits, out_bits: usize) -> u32 {
    let y = bnn::forward(model, x);
    y.words().first().copied().unwrap_or(0) & out_mask(out_bits.min(32))
}

/// One random hot-swap scenario: a reader thread classifies batches
/// while the main thread swaps between two same-architecture models;
/// every prediction must match one of the two models exactly and the
/// observed version sequence must be monotone.
fn check_swap_consistency(rng: &mut Rng) -> Result<(), String> {
    let in_bits = pow2_in(rng, 16, 64);
    let out_neurons = 1 + rng.gen_range(0, 16);
    let layers = vec![out_neurons];
    let seed_a = rng.next_u64();
    let seed_b = rng.next_u64();
    let model_a = BnnModel::random(in_bits, &layers, seed_a);
    let model_b = BnnModel::random(in_bits, &layers, seed_b);
    let kind = if rng.gen_bool(0.5) {
        BackendKind::Batched
    } else {
        BackendKind::Scalar
    };
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 0 })
        .backend(kind)
        .model("m", model_a.clone())
        .build()
        .map_err(|e| format!("deploy {in_bits}b->{layers:?}: {e}"))?;

    let batch_size = 1 + rng.gen_range(0, 48);
    let n_batches = 6 + rng.gen_range(0, 6);
    let n_swaps = 3usize;
    let input_seed = rng.next_u64();

    let stop = AtomicBool::new(false);
    let result = std::thread::scope(|scope| -> Result<(), String> {
        let reader = scope.spawn(|| -> Result<(), String> {
            let mut session = deployment
                .session("m")
                .map_err(|e| e.to_string())?;
            let mut rng = Rng::seed_from_u64(input_seed);
            let mut last_version = 0u64;
            for batch in 0..n_batches {
                let inputs: Vec<PackedBits> =
                    (0..batch_size).map(|_| PackedBits::random(in_bits, &mut rng)).collect();
                let frames: Vec<Vec<u8>> = inputs.iter().map(frame_for).collect();
                let refs: Vec<&[u8]> = frames.iter().map(|f| f.as_slice()).collect();
                let mut out = Vec::new();
                let version = session
                    .classify_batch(&refs, &mut out)
                    .map_err(|e| e.to_string())?;
                if version < last_version {
                    return Err(format!(
                        "version counter not monotone: {version} after {last_version}"
                    ));
                }
                last_version = version;
                for (i, x) in inputs.iter().enumerate() {
                    let ea = expect_word(&model_a, x, out_neurons);
                    let eb = expect_word(&model_b, x, out_neurons);
                    let got = out[i];
                    if got != ea && got != eb {
                        return Err(format!(
                            "torn read in batch {batch} lane {i} (v{version}): got \
                             {got:#x}, old model says {ea:#x}, new says {eb:#x}"
                        ));
                    }
                }
                std::thread::yield_now();
            }
            stop.store(true, Ordering::Relaxed);
            Ok(())
        });
        let mut last = 1u64;
        for k in 0..n_swaps {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let next = if k % 2 == 0 { &model_b } else { &model_a };
            let v = deployment
                .swap_model("m", next.clone())
                .map_err(|e| e.to_string())?;
            if v <= last {
                return Err(format!("swap version not monotone: {v} after {last}"));
            }
            last = v;
            std::thread::yield_now();
        }
        reader.join().expect("reader panicked")
    });
    result
}

#[test]
fn prop_h1_concurrent_swap_predictions_never_tear() {
    let cases = prop::default_cases().min(24);
    prop::check("hotswap-consistency", cases, check_swap_consistency);
}

/// H2: hammer the engine path — many swaps against a multi-worker
/// engine run; outputs must each match one of the two models.
#[test]
fn h2_engine_workers_pick_up_swaps_without_tearing() {
    let model_a = BnnModel::random(32, &[16, 1], 71);
    let model_b = BnnModel::random(32, &[16, 1], 72);
    let deployment = Arc::new(
        Deployment::builder()
            .extractor(FieldExtractor::PayloadAt { offset: 0 })
            .workers(4)
            .model("m", model_a.clone())
            .build()
            .unwrap(),
    );
    let mut rng = Rng::seed_from_u64(73);
    let inputs: Vec<PackedBits> =
        (0..4000).map(|_| PackedBits::random(32, &mut rng)).collect();
    let frames: Vec<Vec<u8>> = inputs.iter().map(frame_for).collect();

    let stop = Arc::new(AtomicBool::new(false));
    let swaps_done = Arc::new(AtomicU64::new(0));
    let swapper = {
        let deployment = Arc::clone(&deployment);
        let stop = Arc::clone(&stop);
        let swaps_done = Arc::clone(&swaps_done);
        let (a, b) = (model_a.clone(), model_b.clone());
        std::thread::spawn(move || {
            let mut k = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let next = if k % 2 == 0 { &b } else { &a };
                deployment.swap_model("m", next.clone()).unwrap();
                swaps_done.fetch_add(1, Ordering::Relaxed);
                k += 1;
                std::thread::yield_now();
            }
        })
    };

    let mut last_version = 0u64;
    for _ in 0..5 {
        let report = deployment.serve_trace("m", &frames).unwrap();
        assert!(report.model_version >= last_version, "report version monotone");
        last_version = report.model_version;
        for (i, x) in inputs.iter().enumerate() {
            let ea = expect_word(&model_a, x, 1);
            let eb = expect_word(&model_b, x, 1);
            let got = report.outputs[i];
            assert!(
                got == ea || got == eb,
                "engine torn read at pkt {i}: got {got}, a {ea}, b {eb}"
            );
        }
    }
    stop.store(true, Ordering::Relaxed);
    swapper.join().unwrap();
    assert!(swaps_done.load(Ordering::Relaxed) > 0, "swapper never ran");
    assert_eq!(
        deployment.version("m").unwrap(),
        1 + deployment.stats("m").unwrap().swaps,
        "every successful swap bumps the version exactly once"
    );
}

/// H3: a rejected swap publishes nothing.
#[test]
fn h3_failed_swap_keeps_the_old_model_serving() {
    let model_a = BnnModel::random(32, &[16, 1], 81);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 0 })
        .model("m", model_a.clone())
        .build()
        .unwrap();
    let mut session = deployment.session("m").unwrap();
    let mut rng = Rng::seed_from_u64(82);
    let x = PackedBits::random(32, &mut rng);
    let pkt = frame_for(&x);
    let refs: Vec<&[u8]> = vec![&pkt];
    let mut out = Vec::new();

    assert!(deployment
        .swap_model("m", BnnModel::random(64, &[16, 1], 83))
        .is_err());
    assert_eq!(deployment.version("m").unwrap(), 1);
    assert_eq!(deployment.stats("m").unwrap().swaps, 0);
    assert_eq!(session.classify_batch(&refs, &mut out).unwrap(), 1);
    assert_eq!(out[0], expect_word(&model_a, &x, 1));
}
