//! IR / pass-pipeline / specialized-backend properties (PR 6
//! acceptance):
//!
//! I1. Lowering a compiled program to straight-line IR is bit-exact
//!     with the scalar pipeline on the output containers, for ANY
//!     valid model, chip, and input — including weights-as-immediates
//!     and weights-as-action-data programs.
//! I2. The optimization pipeline (pack, popcount strength reduction,
//!     DCE) preserves every `live_out` register for ANY register
//!     state, on the host pipeline and the chip-faithful pipeline.
//! I3. Every pass is idempotent: a second pipeline run changes
//!     nothing and reports no changes.
//! I4. The specialized backend is bit-exact with the reference
//!     backend through real deployment sessions, malformed frames
//!     included.
//! I5. Under concurrent hot-swap, specialized predictions never tear:
//!     every output matches the old or the new model exactly.
//! I6. Keyed (multi-model) deployments reject the specialized backend
//!     with an enumerated error instead of serving wrong weights.

use std::sync::atomic::{AtomicBool, Ordering};

use n2net::backend::{out_mask, BackendKind};
use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::compiler::ir::IrProgram;
use n2net::compiler::{passes, Compiler, CompilerOptions, InputEncoding};
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::rmt::{ChipConfig, Pipeline};
use n2net::util::prop::{self, pow2_in};
use n2net::util::rng::Rng;

/// Raw little-endian activation frame (PayloadLe { offset: 0 }).
fn frame_for(x: &PackedBits) -> Vec<u8> {
    let mut pkt = Vec::with_capacity(x.words().len() * 4);
    for w in x.words() {
        pkt.extend_from_slice(&w.to_le_bytes());
    }
    pkt
}

/// Expected output word of `model` on `x` under the backend trait's
/// low-output-bits convention.
fn expect_word(model: &BnnModel, x: &PackedBits, out_bits: usize) -> u32 {
    let y = bnn::forward(model, x);
    y.words().first().copied().unwrap_or(0) & out_mask(out_bits.min(32))
}

/// Random feasible spec, biased small for speed (cf. `prop_batch`).
fn random_spec(rng: &mut Rng) -> (usize, Vec<usize>) {
    let in_bits = pow2_in(rng, 16, 256);
    let n_layers = 1 + rng.gen_range(0, 2);
    let mut layers = Vec::new();
    for i in 0..n_layers {
        if i + 1 == n_layers {
            layers.push(1 + rng.gen_range(0, 32));
        } else {
            layers.push(pow2_in(rng, 16, 64));
        }
    }
    (in_bits, layers)
}

/// Compile a random model on `chip` and lower it; returns everything
/// the equivalence checks need.
fn compile_and_lower(
    chip: &ChipConfig,
    rng: &mut Rng,
) -> Result<(BnnModel, n2net::compiler::CompiledModel, IrProgram), String> {
    let (in_bits, layers) = random_spec(rng);
    let seed = rng.next_u64();
    let model = BnnModel::random(in_bits, &layers, seed);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        weights_as_immediates: rng.gen_bool(0.5),
        ..Default::default()
    };
    let compiled = Compiler::new(chip.clone(), opts)
        .compile(&model)
        .map_err(|e| format!("compile {in_bits}b->{layers:?}: {e}"))?;
    let ir = IrProgram::lower(
        &compiled.program,
        &compiled.chip.phv,
        &compiled.layout.output,
    )
    .map_err(|e| format!("lower {in_bits}b->{layers:?} seed {seed:#x}: {e}"))?;
    Ok((model, compiled, ir))
}

/// I1: lowered IR ≡ scalar pipeline on the output containers.
fn check_lowering_equivalence(chip: ChipConfig, rng: &mut Rng) -> Result<(), String> {
    let (model, compiled, ir) = compile_and_lower(&chip, rng)?;
    let mut scalar = Pipeline::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .map_err(|e| e.to_string())?;
    for case in 0..4 {
        let x = PackedBits::random(model.spec.in_bits, rng);
        let frame = frame_for(&x);
        let phv = scalar
            .process_packet(&frame)
            .map_err(|e| format!("case {case}: scalar: {e}"))?;
        // Seed the IR register file exactly like the parser seeds the
        // PHV, then run the straight-line program.
        let mut regs = vec![0u32; ir.n_regs];
        for e in &compiled.parser.extracts {
            let v = e.read_value(&frame).map_err(|e| e.to_string())?;
            regs[e.dst.index()] = v & compiled.chip.phv.mask(e.dst);
        }
        ir.execute(&mut regs);
        for id in &compiled.layout.output {
            if regs[id.index()] != phv.read(*id) {
                return Err(format!(
                    "case {case}: container {id:?} diverged: ir {:#x} \
                     != scalar {:#x}",
                    regs[id.index()],
                    phv.read(*id)
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn i1_lowered_ir_equals_scalar_stock_chip() {
    prop::check("ir≡scalar/stock", prop::default_cases(), |rng| {
        check_lowering_equivalence(ChipConfig::rmt(), rng)
    });
}

#[test]
fn i1_lowered_ir_equals_scalar_native_popcnt() {
    prop::check("ir≡scalar/native", prop::default_cases(), |rng| {
        check_lowering_equivalence(ChipConfig::rmt_with_popcnt(), rng)
    });
}

/// I2 + I3: the pass pipeline preserves `live_out` for random register
/// states and is idempotent.
fn check_pipeline_equivalence(chip: ChipConfig, rng: &mut Rng) -> Result<(), String> {
    let (_, _, base) = compile_and_lower(&chip, rng)?;
    let pipelines: [(&str, Vec<Box<dyn passes::Pass>>); 2] = [
        ("host", passes::host_pipeline()),
        ("chip", passes::chip_pipeline(&chip)),
    ];
    for (which, pipeline) in pipelines {
        let mut opt = base.clone();
        passes::run_pipeline(&mut opt, &pipeline);
        opt.validate().map_err(|e| format!("{which}: invalid after opt: {e}"))?;
        // I3: second run is a no-op, structurally and by report.
        let snapshot = opt.clone();
        let report = passes::run_pipeline(&mut opt, &pipeline);
        if report.iter().any(|&(_, changed)| changed) {
            return Err(format!("{which}: pipeline not idempotent: {report:?}"));
        }
        if opt != snapshot {
            return Err(format!("{which}: second run mutated the program"));
        }
        // I2: bit-exact on live_out over random register states.
        for case in 0..4 {
            let seed: Vec<u32> = (0..base.n_regs).map(|_| rng.next_u32()).collect();
            let mut r0 = seed.clone();
            let mut r1 = seed;
            base.execute(&mut r0);
            opt.execute(&mut r1);
            for &out in &base.live_out {
                if r0[out as usize] != r1[out as usize] {
                    return Err(format!(
                        "{which}: case {case}: r{out} diverged: base {:#x} \
                         != opt {:#x}",
                        r0[out as usize], r1[out as usize]
                    ));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn i2_i3_passes_preserve_live_out_and_are_idempotent_stock() {
    prop::check("passes≡/stock", prop::default_cases(), |rng| {
        check_pipeline_equivalence(ChipConfig::rmt(), rng)
    });
}

#[test]
fn i2_i3_passes_preserve_live_out_and_are_idempotent_native() {
    prop::check("passes≡/native", prop::default_cases(), |rng| {
        check_pipeline_equivalence(ChipConfig::rmt_with_popcnt(), rng)
    });
}

/// I4: specialized ≡ reference through deployment sessions, with
/// malformed frames mixed in.
fn check_specialized_serving(rng: &mut Rng) -> Result<(), String> {
    let (in_bits, layers) = random_spec(rng);
    let seed = rng.next_u64();
    let model = BnnModel::random(in_bits, &layers, seed);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 0 })
        .model("m", model.clone())
        .build()
        .map_err(|e| format!("deploy {in_bits}b->{layers:?}: {e}"))?;
    let mut spec = deployment
        .session_with("m", BackendKind::Specialized)
        .map_err(|e| format!("open specialized: {e}"))?;
    let mut reference = deployment
        .session_with("m", BackendKind::Reference)
        .map_err(|e| format!("open reference: {e}"))?;
    if spec.backend_name() != "specialized" {
        return Err(format!("backend name {:?}", spec.backend_name()));
    }

    let batch_size = 1 + rng.gen_range(0, 48);
    let mut frames: Vec<Vec<u8>> = Vec::with_capacity(batch_size);
    let mut inputs: Vec<Option<PackedBits>> = Vec::with_capacity(batch_size);
    for _ in 0..batch_size {
        let x = PackedBits::random(in_bits, rng);
        let mut frame = frame_for(&x);
        if rng.gen_range(0, 6) == 0 && !frame.is_empty() {
            frame.truncate(rng.gen_range(0, frame.len()));
            inputs.push(None);
        } else {
            inputs.push(Some(x));
        }
        frames.push(frame);
    }
    let refs: Vec<&[u8]> = frames.iter().map(|f| f.as_slice()).collect();
    let mut got = Vec::new();
    let mut want = Vec::new();
    spec.classify_batch(&refs, &mut got).map_err(|e| e.to_string())?;
    reference.classify_batch(&refs, &mut want).map_err(|e| e.to_string())?;
    if got != want {
        return Err(format!(
            "specialized != reference ({in_bits}b->{layers:?} seed {seed:#x}): \
             {got:?} vs {want:?}"
        ));
    }
    let out_bits = *layers.last().unwrap();
    for (i, input) in inputs.iter().enumerate() {
        match input {
            None if got[i] != 0 => {
                return Err(format!("lane {i}: malformed frame classified {:#x}", got[i]))
            }
            Some(x) if got[i] != expect_word(&model, x, out_bits) => {
                return Err(format!(
                    "lane {i}: {:#x} != forward {:#x}",
                    got[i],
                    expect_word(&model, x, out_bits)
                ))
            }
            _ => {}
        }
    }
    Ok(())
}

#[test]
fn i4_specialized_equals_reference_in_deployment() {
    prop::check("specialized≡reference", prop::default_cases(), check_specialized_serving);
}

/// I5: concurrent hot-swap never tears specialized predictions (the
/// specialized program is rebuilt from the published artifact at batch
/// boundaries, exactly like the other backends).
#[test]
fn i5_specialized_survives_concurrent_hotswap() {
    let in_bits = 32usize;
    let out_neurons = 8usize;
    let model_a = BnnModel::random(in_bits, &[16, out_neurons], 61);
    let model_b = BnnModel::random(in_bits, &[16, out_neurons], 62);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 0 })
        .backend(BackendKind::Specialized)
        .model("m", model_a.clone())
        .build()
        .unwrap();

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let reader = scope.spawn(|| {
            let mut session = deployment.session("m").unwrap();
            assert_eq!(session.backend_name(), "specialized");
            let mut rng = Rng::seed_from_u64(63);
            let mut last_version = 0u64;
            for batch in 0..10 {
                let inputs: Vec<PackedBits> =
                    (0..32).map(|_| PackedBits::random(in_bits, &mut rng)).collect();
                let frames: Vec<Vec<u8>> = inputs.iter().map(frame_for).collect();
                let refs: Vec<&[u8]> = frames.iter().map(|f| f.as_slice()).collect();
                let mut out = Vec::new();
                let version = session.classify_batch(&refs, &mut out).unwrap();
                assert!(version >= last_version, "version monotone");
                last_version = version;
                for (i, x) in inputs.iter().enumerate() {
                    let ea = expect_word(&model_a, x, out_neurons);
                    let eb = expect_word(&model_b, x, out_neurons);
                    assert!(
                        out[i] == ea || out[i] == eb,
                        "torn read in batch {batch} lane {i}: got {:#x}, \
                         old {ea:#x}, new {eb:#x}",
                        out[i]
                    );
                }
                std::thread::yield_now();
            }
            stop.store(true, Ordering::Relaxed);
        });
        let mut k = 0usize;
        while !stop.load(Ordering::Relaxed) && k < 64 {
            let next = if k % 2 == 0 { &model_b } else { &model_a };
            deployment.swap_model("m", next.clone()).unwrap();
            k += 1;
            std::thread::yield_now();
        }
        reader.join().expect("reader panicked");
    });
}

/// I6: keyed deployments reject the specialized backend up front.
#[test]
fn i6_keyed_deployment_rejects_specialized() {
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 4 })
        .keyed(0)
        .model("a", BnnModel::random(32, &[16, 1], 91))
        .model("b", BnnModel::random(32, &[16, 1], 92))
        .build()
        .unwrap();
    let err = match deployment.keyed_session_with(BackendKind::Specialized) {
        Ok(_) => panic!("specialized must be rejected on keyed deployments"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("isolated deployment"), "{err}");
    assert!(err.contains("scalar|batched"), "{err}");
}
