//! Static-verifier diagnostics (ISSUE 8 acceptance):
//!
//! V1. Golden tests: hand-built illegal programs produce the EXACT
//!     `Violation` list — kind, severity, stage, and op provenance —
//!     for each seeded-illegal class (overflow, undefined read,
//!     over-budget element, recirculation, unwritten output).
//! V2. Property: everything `Compiler::compile` (and `compile_multi`)
//!     accepts passes verification with zero errors — and with zero
//!     warnings when the program fits in one pipeline pass (the
//!     `check --deny-warnings` CI contract).
//! V3. Translation validation: the honest pass pipeline validates
//!     (pack and DCE *proven*, strength reduction *sampled*), and a
//!     deliberately semantics-breaking pass is rejected with
//!     `Error::Verify` while the IR rolls back to the last validated
//!     state.

use n2net::bnn::BnnModel;
use n2net::compiler::ir::{IrBlock, IrInstr, IrOp, IrProgram, Operand, RegId};
use n2net::compiler::passes::{self, Pass};
use n2net::compiler::verify::{self, Equivalence, Severity, ViolationKind};
use n2net::compiler::{
    Compiler, CompilerOptions, InputEncoding, MultiModelOptions,
};
use n2net::error::Error;
use n2net::rmt::{
    AluOp, ChipConfig, ContainerId, Element, MicroOp, Src, StepKind,
};
use n2net::util::prop::{self, pow2_in};
use n2net::util::rng::Rng;

fn instr(op: IrOp, dst: RegId, a: Operand, b: Operand) -> IrInstr {
    IrInstr { op, dst, dst2: dst, a, b, aux: 0, gather: Vec::new() }
}

fn one_block(
    instrs: Vec<IrInstr>,
    n_regs: usize,
    masks: Vec<u32>,
    live_out: Vec<RegId>,
) -> IrProgram {
    IrProgram {
        blocks: vec![IrBlock { label: "t".into(), step: StepKind::Other, instrs }],
        n_containers: n_regs,
        n_regs,
        live_out,
        masks,
    }
}

/// The provenance tuple the golden tests pin.
fn shape(v: &verify::Violation) -> (ViolationKind, Severity, Option<usize>, Option<usize>) {
    (v.kind, v.severity, v.stage, v.op)
}

// ---------------------------------------------------------------------------
// V1 — golden diagnostics
// ---------------------------------------------------------------------------

#[test]
fn golden_narrow_container_overflow() {
    // r0 is an 8-bit container; Add's ideal bound 0xFF + 0xFF = 0x1FE
    // cannot be stored without truncation.
    let ir = one_block(
        vec![
            instr(IrOp::Mov, 2, Operand::Reg(1), Operand::Imm(0)),
            instr(IrOp::Add, 0, Operand::Reg(2), Operand::Reg(2)),
        ],
        3,
        vec![0xFF, 0xFF, 0xFF],
        vec![0],
    );
    let report = verify::verify_ir(&ir, &[1]);
    let shapes: Vec<_> = report.violations.iter().map(shape).collect();
    assert_eq!(
        shapes,
        vec![(ViolationKind::Overflow, Severity::Error, Some(0), Some(1))],
        "{}",
        report.render()
    );
    assert!(report.violations[0].message.contains("0x1fe"), "{}", report.render());
}

#[test]
fn golden_undefined_read_reports_first_use_only() {
    // r3 is never written: flagged at its FIRST read (op 1), and only
    // once even though op 2 reads it again.
    let ir = one_block(
        vec![
            instr(IrOp::Mov, 1, Operand::Reg(0), Operand::Imm(0)),
            instr(IrOp::Add, 2, Operand::Reg(3), Operand::Reg(1)),
            instr(IrOp::Or, 2, Operand::Reg(3), Operand::Reg(2)),
        ],
        4,
        vec![u32::MAX; 4],
        vec![2],
    );
    let report = verify::verify_ir(&ir, &[0]);
    let shapes: Vec<_> = report.violations.iter().map(shape).collect();
    assert_eq!(
        shapes,
        vec![(ViolationKind::UndefinedRead, Severity::Error, Some(0), Some(1))],
        "{}",
        report.render()
    );
    assert!(report.violations[0].message.contains("r3"), "{}", report.render());
}

#[test]
fn golden_unwritten_live_out() {
    let ir = one_block(
        vec![instr(IrOp::Mov, 1, Operand::Reg(0), Operand::Imm(0))],
        3,
        vec![u32::MAX; 3],
        vec![1, 2],
    );
    let report = verify::verify_ir(&ir, &[0]);
    let shapes: Vec<_> = report.violations.iter().map(shape).collect();
    assert_eq!(
        shapes,
        vec![(ViolationKind::UnwrittenOutput, Severity::Error, None, None)],
        "{}",
        report.render()
    );
    assert!(report.violations[0].message.contains("r2"), "{}", report.render());
}

#[test]
fn golden_over_budget_element() {
    // 8 one-slot ops on a 4-slot chip: exactly one op-budget error with
    // element provenance, nothing else (the ops themselves are legal).
    let chip = ChipConfig { max_ops_per_element: 4, ..ChipConfig::rmt() };
    let ops: Vec<MicroOp> = (1..=8)
        .map(|i| MicroOp::Alu {
            dst: ContainerId(i),
            op: AluOp::Mov,
            a: Src::Container(ContainerId(0)),
            b: Src::Imm(0),
        })
        .collect();
    let program = n2net::rmt::Program::new(vec![Element::new(
        "fat",
        StepKind::Other,
        ops,
    )]);
    let report = verify::verify_program(&program, &chip, &[ContainerId(0)]);
    let shapes: Vec<_> = report.violations.iter().map(shape).collect();
    assert_eq!(
        shapes,
        vec![(ViolationKind::OpBudget, Severity::Error, Some(0), None)],
        "{}",
        report.render()
    );
    assert_eq!(report.violations[0].label, "fat");
    assert!(report.violations[0].message.contains("8"), "{}", report.render());
}

#[test]
fn golden_recirculation_is_a_warning() {
    let chip = ChipConfig { n_elements: 1, ..ChipConfig::rmt() };
    let element = |label: &str, dst: u16| {
        Element::new(
            label,
            StepKind::Other,
            vec![MicroOp::Alu {
                dst: ContainerId(dst),
                op: AluOp::Mov,
                a: Src::Container(ContainerId(0)),
                b: Src::Imm(0),
            }],
        )
    };
    let program =
        n2net::rmt::Program::new(vec![element("e0", 1), element("e1", 2)]);
    let report = verify::verify_program(&program, &chip, &[ContainerId(0)]);
    let shapes: Vec<_> = report.violations.iter().map(shape).collect();
    assert_eq!(
        shapes,
        vec![(ViolationKind::Recirculation, Severity::Warning, None, None)],
        "{}",
        report.render()
    );
    assert!(report.ok(false) && !report.ok(true), "warnings gate only under deny");
}

#[test]
fn golden_undefined_container_read_in_program() {
    // Container 5 is neither extracted nor written by an earlier
    // element — element-level dataflow must catch it with op provenance.
    let chip = ChipConfig::rmt();
    let program = n2net::rmt::Program::new(vec![Element::new(
        "leaky",
        StepKind::Other,
        vec![
            MicroOp::Alu {
                dst: ContainerId(1),
                op: AluOp::Mov,
                a: Src::Container(ContainerId(0)),
                b: Src::Imm(0),
            },
            MicroOp::Alu {
                dst: ContainerId(2),
                op: AluOp::And,
                a: Src::Container(ContainerId(5)),
                b: Src::Imm(1),
            },
        ],
    )]);
    let report = verify::verify_program(&program, &chip, &[ContainerId(0)]);
    let shapes: Vec<_> = report.violations.iter().map(shape).collect();
    assert_eq!(
        shapes,
        vec![(ViolationKind::UndefinedRead, Severity::Error, Some(0), Some(1))],
        "{}",
        report.render()
    );
}

// ---------------------------------------------------------------------------
// V2 — whatever the compiler accepts, the verifier accepts
// ---------------------------------------------------------------------------

/// Random feasible spec, biased small for speed (cf. `prop_ir`).
fn random_spec(rng: &mut Rng) -> (usize, Vec<usize>) {
    let in_bits = pow2_in(rng, 16, 256);
    let n_layers = 1 + rng.gen_range(0, 2);
    let mut layers = Vec::new();
    for i in 0..n_layers {
        if i + 1 == n_layers {
            layers.push(1 + rng.gen_range(0, 32));
        } else {
            layers.push(pow2_in(rng, 16, 64));
        }
    }
    (in_bits, layers)
}

#[test]
fn prop_compiler_output_always_verifies() {
    prop::check("compiled-verifies", prop::default_cases(), |rng| {
        let (in_bits, layers) = random_spec(rng);
        let model = BnnModel::random(in_bits, &layers, rng.next_u64());
        let chip = if rng.gen_bool(0.5) {
            ChipConfig::rmt()
        } else {
            ChipConfig::rmt_with_popcnt()
        };
        let opts = CompilerOptions {
            input: InputEncoding::PayloadLe { offset: 0 },
            ..Default::default()
        };
        let compiled = Compiler::new(chip, opts)
            .compile(&model)
            .map_err(|e| format!("compile failed: {e}"))?;
        let report = compiled.verify();
        if report.has_errors() {
            return Err(format!(
                "{in_bits}b -> {layers:?}: compiler output rejected:\n{}",
                report.render()
            ));
        }
        // Single-pass programs must be COMPLETELY clean — this is what
        // lets CI run `check --deny-warnings`. Multi-pass programs are
        // allowed exactly their recirculation warning.
        if compiled.resources.passes == 1 && !report.is_clean() {
            return Err(format!("unexpected warnings:\n{}", report.render()));
        }
        Ok(())
    });
}

#[test]
fn prop_keyed_programs_verify_through_the_program_layer() {
    prop::check("keyed-verifies", prop::default_cases() / 2, |rng| {
        let in_bits = pow2_in(rng, 16, 64);
        let layers = vec![1 + rng.gen_range(0, 16)];
        let pairs: Vec<(u32, BnnModel)> = (0..2)
            .map(|i| (i + 1, BnnModel::random(in_bits, &layers, rng.next_u64())))
            .collect();
        let opts = CompilerOptions {
            input: InputEncoding::PayloadLe { offset: 4 },
            ..Default::default()
        };
        let compiled = Compiler::new(ChipConfig::rmt(), opts)
            .compile_multi(&pairs, MultiModelOptions { id_offset: 0 })
            .map_err(|e| format!("compile_multi failed: {e}"))?;
        let report = compiled.verify();
        if report.has_errors() {
            return Err(format!("keyed program rejected:\n{}", report.render()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// V3 — translation validation
// ---------------------------------------------------------------------------

fn lowered_ir(chip: ChipConfig) -> IrProgram {
    let model = BnnModel::random(64, &[32, 8], 11);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        ..Default::default()
    };
    let compiled = Compiler::new(chip, opts).compile(&model).unwrap();
    IrProgram::lower(&compiled.program, &compiled.chip.phv, &compiled.layout.output)
        .unwrap()
}

#[test]
fn honest_pipeline_validates_with_expected_equivalence_classes() {
    let mut ir = lowered_ir(ChipConfig::rmt());
    let mut reduced = false;
    for pass in passes::host_pipeline() {
        let pre = ir.clone();
        let changed = pass.run(&mut ir);
        if !changed {
            continue;
        }
        let how = verify::equivalent_on_live_out(&pre, &ir, verify::TV_SAMPLES)
            .unwrap_or_else(|why| panic!("pass '{}' diverged: {why}", pass.name()));
        match pass.name() {
            // Structural rewrites: the symbolic summaries are identical.
            "pack-stages" | "dead-code-eliminate" => {
                assert_eq!(how, Equivalence::Proven, "pass '{}'", pass.name())
            }
            // The SWAR tree -> Popcnt rewrite is structurally different;
            // only the concrete-sampling fallback can accept it.
            "popcount-strength-reduce" => {
                reduced = true;
                assert_eq!(how, Equivalence::Sampled, "pass '{}'", pass.name())
            }
            other => panic!("unexpected pass {other:?}"),
        }
    }
    assert!(reduced, "host pipeline must strength-reduce the stock-chip tree");
}

/// A pass that deletes stores from the tail of the program up to and
/// including the last store to a `live_out` register — exactly the
/// kind of optimizer bug translation validation exists to catch
/// (DCE-gone-wrong: "dead" code that wasn't).
struct DropFinalStore;

impl Pass for DropFinalStore {
    fn name(&self) -> &'static str {
        "drop-final-store"
    }
    fn run(&self, ir: &mut IrProgram) -> bool {
        let live = ir.live_out.clone();
        for block in ir.blocks.iter_mut().rev() {
            while let Some(i) = block.instrs.pop() {
                if live.contains(&i.dst) || live.contains(&i.dst2) {
                    return true;
                }
            }
        }
        false
    }
}

/// A pass that appends a complement of an output register — a
/// value-level miscompile that keeps the program structurally valid,
/// so only the concrete comparison can see it. The complement differs
/// from the original on EVERY input (even under a narrow store mask),
/// so the sampling fallback is guaranteed to catch it.
struct NegateOutput;

impl Pass for NegateOutput {
    fn name(&self) -> &'static str {
        "negate-output"
    }
    fn run(&self, ir: &mut IrProgram) -> bool {
        let Some(&d) = ir.live_out.first() else { return false };
        let Some(block) = ir.blocks.last_mut() else { return false };
        block.instrs.push(IrInstr {
            op: IrOp::Not,
            dst: d,
            dst2: d,
            a: Operand::Reg(d),
            b: Operand::Imm(0),
            aux: 0,
            gather: Vec::new(),
        });
        true
    }
}

fn assert_rejected(pipeline: Vec<Box<dyn Pass>>, name: &str) {
    let mut ir = lowered_ir(ChipConfig::rmt());
    let pristine = ir.clone();
    let err = passes::run_pipeline_validated(&mut ir, &pipeline)
        .err()
        .unwrap_or_else(|| panic!("{name} must be rejected"));
    match err {
        Error::Verify(msg) => {
            assert!(msg.contains(name), "diagnostic names the pass: {msg}");
            assert!(
                msg.contains("translation validation"),
                "diagnostic names the check: {msg}"
            );
        }
        other => panic!("expected Error::Verify, got {other}"),
    }
    // Rollback: the caller still holds the last validated program.
    assert_eq!(ir, pristine, "IR must roll back after {name}");
}

#[test]
fn semantics_breaking_passes_are_rejected_and_rolled_back() {
    assert_rejected(vec![Box::new(DropFinalStore)], "drop-final-store");
    assert_rejected(vec![Box::new(NegateOutput)], "negate-output");
}

#[test]
fn validated_pipeline_matches_the_unvalidated_one() {
    let mut a = lowered_ir(ChipConfig::rmt());
    let mut b = a.clone();
    let report = passes::run_pipeline_validated(&mut a, &passes::host_pipeline())
        .expect("honest pipeline validates");
    passes::run_pipeline(&mut b, &passes::host_pipeline());
    assert_eq!(a, b, "validation must not change what the pipeline produces");
    assert!(report.iter().any(|&(_, changed)| changed));
}
