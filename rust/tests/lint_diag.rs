//! Golden diagnostics for `controlplane::lint` (ISSUE 10 acceptance):
//! every shipped example policy produces exactly the findings its
//! header comment promises — the good corpus is clean under
//! `deny_warnings`, and each bad policy trips its named code — and the
//! structured codes cover swap-cycle oscillation, shadowed rule,
//! unreachable rule, unknown swap target, keyed+specialized
//! illegality, and both modeled-SLO threshold pathologies.

use n2net::backend::BackendKind;
use n2net::bnn::BnnModel;
use n2net::controlplane::{LintKind, LintReport, Linter, ModelBank, Policy, SloBounds};
use n2net::timing::ModeledSlo;

fn corpus(rel: &str) -> String {
    let path = format!(
        "{}/../examples/policies/{rel}",
        env!("CARGO_MANIFEST_DIR")
    );
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("corpus file {path}: {e}"))
}

/// The adaptive-serving shape `n2net lint` builds: a "day" default plus
/// a same-architecture "attack" candidate, 2 shards, batched.
fn bank() -> (ModelBank, BnnModel) {
    let day = BnnModel::random(32, &[64, 32], 1);
    let bank = ModelBank::new("day", day.clone())
        .with_model("attack", BnnModel::random(32, &[64, 32], 2));
    (bank, day)
}

fn lint_text(text: &str, keyed: bool) -> LintReport {
    let policy = Policy::parse(text).expect("corpus policy parses");
    let (bank, day) = bank();
    let mut linter = Linter::new(&policy)
        .with_bank(&bank)
        .with_deployed(&day.spec)
        .with_tier_shape(2, BackendKind::Batched);
    if keyed {
        linter = linter.keyed();
    }
    linter.lint()
}

fn codes(report: &LintReport) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.kind.code()).collect()
}

#[test]
fn good_corpus_and_builtin_default_are_clean_under_deny_warnings() {
    // The built-in default policy (main.rs `policy_for`) ships as
    // good/default.policy — this test pins that the copy IS the
    // built-in (same rules after parsing) and that every good policy
    // lints clean, warnings included.
    for name in ["good/default.policy", "good/escalation.policy", "good/recovery.policy"]
    {
        let report = lint_text(&corpus(name), false);
        assert!(
            report.is_clean(),
            "{name} must lint clean:\n{}",
            report.render()
        );
        assert!(report.ok(true), "{name} must pass --deny-warnings");
    }
    let builtin = "on ddos-ramp do swap attack cooldown=4\n\
                   on overload do alert cooldown=8\n\
                   on drift do alert cooldown=8\n\
                   on imbalance do alert cooldown=8\n\
                   on latency-slo do alert cooldown=8\n";
    let from_file = Policy::parse(&corpus("good/default.policy")).unwrap();
    let from_builtin = Policy::parse(builtin).unwrap();
    assert_eq!(from_file.render(), from_builtin.render(),
        "good/default.policy must stay in sync with the built-in policy");
}

#[test]
fn oscillate_policy_is_a_swap_cycle_error() {
    let report = lint_text(&corpus("bad/oscillate.policy"), false);
    assert_eq!(codes(&report), vec!["swap-cycle"], "{}", report.render());
    assert!(report.has_errors());
    let f = &report.findings[0];
    assert!(f.message.contains("self-sustaining"), "{}", f.message);
    assert!(
        f.message.contains("cooldown only bounds the flap period"),
        "the hysteresis argument must be spelled out: {}",
        f.message
    );
    // The rendered line carries the kebab code and the rule provenance.
    let line = f.to_string();
    assert!(line.starts_with("error[swap-cycle] rule "), "{line}");
    assert!(line.contains("on ddos-ramp do swap attack"), "{line}");
}

#[test]
fn shadowed_policy_is_a_warning_that_deny_warnings_flips() {
    let report = lint_text(&corpus("bad/shadowed.policy"), false);
    assert_eq!(codes(&report), vec!["shadowed-rule"], "{}", report.render());
    assert!(!report.has_errors());
    assert!(report.ok(false), "warning-only run passes plain lint");
    assert!(!report.ok(true), "--deny-warnings flips it to failure");
    let f = &report.findings[0];
    assert_eq!(f.rule, Some(1), "the LATER rule is the shadowed one");
    assert!(f.message.contains("shadowed by rule 0"), "{}", f.message);
}

#[test]
fn unreachable_policy_warns_per_dead_rule_with_the_bound() {
    let report = lint_text(&corpus("bad/unreachable.policy"), false);
    assert_eq!(
        codes(&report),
        vec!["unreachable-rule", "unreachable-rule", "unreachable-rule"],
        "{}",
        report.render()
    );
    let msgs: Vec<&str> =
        report.findings.iter().map(|f| f.message.as_str()).collect();
    assert!(msgs[0].contains("1.5") && msgs[0].contains("drift severity 1"), "{}", msgs[0]);
    assert!(msgs[1].contains("ddos-ramp severity 1"), "{}", msgs[1]);
    assert!(msgs[2].contains("imbalance severity 2"), "{}", msgs[2]);
}

#[test]
fn unknown_swap_target_reuses_the_controller_message() {
    let report = lint_text(&corpus("bad/unknown-target.policy"), false);
    assert_eq!(codes(&report), vec!["unknown-swap-target"], "{}", report.render());
    let f = &report.findings[0];
    assert!(
        f.message.contains("\"nightshift\"") && f.message.contains("model bank"),
        "must carry the Controller's own wording: {}",
        f.message
    );
}

#[test]
fn keyed_specialized_is_only_illegal_when_keyed() {
    let text = corpus("bad/keyed-specialized.policy");
    let isolated = lint_text(&text, false);
    assert!(isolated.is_clean(), "isolated deployment:\n{}", isolated.render());
    let keyed = lint_text(&text, true);
    assert_eq!(codes(&keyed), vec!["keyed-specialized"], "{}", keyed.render());
    assert!(keyed.has_errors());
    assert!(
        keyed.findings[0].message.contains("per-packet model ids"),
        "{}",
        keyed.findings[0].message
    );
}

#[test]
fn lut_and_reshard_range_are_construction_grade_errors() {
    let report = lint_text(&corpus("bad/lut.policy"), false);
    assert_eq!(codes(&report), vec!["lut-switch-target"], "{}", report.render());
    assert!(report.findings[0].message.contains("exact-match table"));

    let report = lint_text(&corpus("bad/reshard-range.policy"), false);
    assert_eq!(codes(&report), vec!["reshard-range"], "{}", report.render());
    assert!(report.findings[0].message.contains("1..=64"));
}

#[test]
fn incompatible_swap_target_is_an_architecture_proof() {
    // Not corpus-expressible (needs a mismatched bank): a bank whose
    // "attack" artifact has a different architecture than the deployed
    // program turns `swap attack` into a statically-provable no-op.
    let day = BnnModel::random(32, &[64, 32], 1);
    let bank = ModelBank::new("day", day.clone())
        .with_model("attack", BnnModel::random(64, &[32, 8], 2));
    let policy = Policy::parse(&corpus("good/recovery.policy")).unwrap();
    let report = Linter::new(&policy)
        .with_bank(&bank)
        .with_deployed(&day.spec)
        .with_tier_shape(2, BackendKind::Batched)
        .lint();
    assert_eq!(
        codes(&report),
        vec!["incompatible-swap-target"],
        "{}",
        report.render()
    );
    assert!(report.findings[0].message.contains("publish gate"));
}

#[test]
fn modeled_slo_thresholds_always_and_never_fire_with_computed_bounds() {
    // A 30-stage single-pass program on the stock chip: fill 410
    // cycles at 960 MHz → floor ≈ 427 ns; 512 packets on one shard
    // drain in ≈ 960 ns.
    let slo = ModeledSlo { fill_cycles: 410, slots_per_packet: 1, clock_hz: 960e6 };
    let policy = Policy::parse("on latency-slo do alert cooldown=8\n").unwrap();
    let (bank, day) = bank();
    let with_limit = |limit: f64| {
        Linter::new(&policy)
            .with_bank(&bank)
            .with_deployed(&day.spec)
            .with_tier_shape(2, BackendKind::Batched)
            .with_modeled_slo(SloBounds {
                slo,
                p50_limit_ns: limit,
                p99_limit_ns: limit,
                window_packets: 512,
            })
            .lint()
    };
    // Below the drain floor: fires on every window — an ERROR.
    let report = with_limit(100.0);
    assert_eq!(codes(&report), vec!["slo-always-fires"], "{}", report.render());
    assert!(report.has_errors());
    assert!(
        report.findings[0].message.contains("427"),
        "computed floor must be in the message: {}",
        report.findings[0].message
    );
    // Above any reachable queue depth: never fires — a WARNING.
    let report = with_limit(1e6);
    assert_eq!(codes(&report), vec!["slo-never-fires"], "{}", report.render());
    assert!(!report.has_errors() && !report.ok(true));
    assert!(
        report.findings[0].message.contains("960"),
        "computed worst drain must be in the message: {}",
        report.findings[0].message
    );
    // A sane limit between the two bounds: clean.
    let report = with_limit(700.0);
    assert!(report.is_clean(), "{}", report.render());
    assert_eq!(report.findings.len(), 0);
    assert_eq!(LintKind::SloAlwaysFires.code(), "slo-always-fires");
}
