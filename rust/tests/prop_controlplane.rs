//! Closed-loop control-plane properties (ISSUE 4 acceptance):
//!
//! C1. Under a scripted ddos-burst sequence the controller swaps AT
//!     MOST once per ramp (the policy engine's hysteresis), and never
//!     outside one (no false swaps).
//! C2. Every served packet is classified by either the pre-swap or the
//!     post-swap model version — the H1 old-or-new invariant carried up
//!     through the control plane. The sim's window discipline makes the
//!     stronger split provable: everything before the swap boundary is
//!     bit-exact with the old model, everything after with the new one.
//! C3. A policy that swaps to an architecture-incompatible bank
//!     artifact is rejected by the deployment without disturbing the
//!     live model: version unmoved, every output still the old model's.

use std::sync::Arc;

use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::controlplane::{
    prefix_classifier, sim_ddos, Controller, ModelBank, Policy, Sim, SimConfig,
};
use n2net::deploy::{Deployment, FieldExtractor, SwapHandle};
use n2net::net::{Scenario, ScenarioSequence};
use n2net::util::prop;
use n2net::util::rng::Rng;

fn deployment_for(live: &BnnModel) -> Arc<Deployment> {
    Arc::new(
        Deployment::builder()
            .extractor(FieldExtractor::SrcIp)
            .model("live", live.clone())
            .build()
            .unwrap(),
    )
}

fn expect_bit(model: &BnnModel, key: u32) -> u32 {
    bnn::forward(model, &PackedBits::from_u32(key)).get(0) as u32
}

/// One random closed-loop scenario: random window size, shard count,
/// attack peak, cooldown and seed; a uniform → ddos-burst → uniform
/// sequence served under a swap-on-ramp policy.
fn check_adaptive_loop(rng: &mut Rng) -> Result<(), String> {
    let window_packets = 128 << rng.gen_range(0, 2); // 128 | 256
    let n_shards = 1 + rng.gen_range(0, 3); // 1..=3
    let peak = 0.7 + 0.25 * rng.gen_f64();
    let cooldown = 2 + rng.gen_range(0, 6);
    let seed = rng.next_u64();
    let quiet_windows = 2 + rng.gen_range(0, 3);

    let live = prefix_classifier(0xC0A8_0000);
    let attack = prefix_classifier(0xC0A8_FFFF);
    let dep = deployment_for(&live);
    let bank = ModelBank::new("day", live.clone()).with_model("attack", attack.clone());
    // min-severity keeps sampling noise on small windows from ever
    // reading as a ramp; the true ramp crosses it comfortably.
    let policy = Policy::parse(&format!(
        "on ddos-ramp do swap attack cooldown={cooldown} min-severity=0.15"
    ))
    .map_err(|e| e.to_string())?;
    let cfg = SimConfig { n_shards, window_packets, seed };
    let seq = ScenarioSequence::new(vec![
        (Scenario::Uniform, window_packets * quiet_windows),
        (
            Scenario::DdosBurst { ddos: sim_ddos(), peak_fraction: peak },
            window_packets * 8,
        ),
        (Scenario::Uniform, window_packets * quiet_windows),
    ]);
    let mut sim =
        Sim::new(&dep, "live", bank, policy, cfg).map_err(|e| e.to_string())?;
    let report = sim.run_sequence(&seq).map_err(|e| e.to_string())?;

    // C1: hysteresis — at most one publication for the single ramp,
    // none outside it.
    if report.swaps.len() > 1 {
        return Err(format!(
            "{} swaps for one ramp (window={window_packets} shards={n_shards} \
             cooldown={cooldown}):\n{}",
            report.swaps.len(),
            report.render()
        ));
    }
    if report.false_swaps != 0 {
        return Err(format!("false swaps:\n{}", report.render()));
    }
    if report.rejected_swaps != 0 {
        return Err("compatible artifact must never be rejected".into());
    }

    // C2: old-or-new, in its strongest window-aligned form.
    let st = seq.generate(seed);
    let boundary = report.swap_boundary().unwrap_or(report.outputs.len());
    for (i, &key) in st.trace.keys.iter().enumerate() {
        let served = report.outputs[i];
        let (model, side) = if i < boundary {
            (&live, "pre")
        } else {
            (&attack, "post")
        };
        let expect = expect_bit(model, key);
        if served != expect {
            let other = if i < boundary {
                expect_bit(&attack, key)
            } else {
                expect_bit(&live, key)
            };
            return Err(format!(
                "pkt {i} ({side}-swap, boundary {boundary}): served {served}, \
                 {side}-model says {expect} (other model {other})"
            ));
        }
    }
    if !report.swaps.is_empty() && report.swaps[0].version != 2 {
        return Err(format!("swap version {} != 2", report.swaps[0].version));
    }
    Ok(())
}

#[test]
fn prop_c1_c2_one_swap_per_ramp_and_old_or_new_outputs() {
    let cases = prop::default_cases().min(12);
    prop::check("controlplane-adaptive-loop", cases, check_adaptive_loop);
}

/// Satellite (ISSUE 5): bad policies fail FAST — at controller
/// construction, with the legal vocabulary enumerated — not when a rule
/// first fires mid-incident.
#[test]
fn bad_policy_targets_fail_at_construction_with_enumerated_vocabulary() {
    let live = prefix_classifier(0xC0A8_0000);
    let dep = deployment_for(&live);
    let handle = || SwapHandle::new(&dep, "live").unwrap();
    let bank =
        || ModelBank::new("day", live.clone()).with_model("night", live.clone());

    // Swap target not in the bank: the error names every bank entry.
    let policy = Policy::parse("on ddos-ramp do swap dusk").unwrap();
    let err = match Controller::new(handle(), bank(), policy) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("unbanked swap target must fail at construction"),
    };
    assert!(err.contains("day") && err.contains("night"), "{err}");

    // Reshard out of the legal range: the error states the range.
    for n in ["0", "65", "10000"] {
        let text = format!("on imbalance do reshard {n}");
        match Policy::parse(&text) {
            // reshard 0 is already a grammar error; larger counts parse
            // and must be range-checked at construction.
            Err(e) => assert!(e.to_string().contains(">= 1"), "{e}"),
            Ok(policy) => {
                let err = match Controller::new(handle(), bank(), policy) {
                    Err(e) => e.to_string(),
                    Ok(_) => panic!("reshard {n} must fail at construction"),
                };
                assert!(err.contains("1..=64"), "range enumerated: {err}");
            }
        }
    }

    // Backend arguments: unknown kinds die in the parser (enumerating
    // the vocabulary); the lut baseline parses but is never a legal
    // switch target.
    let err = Policy::parse("on overload do backend gpu").unwrap_err().to_string();
    assert!(err.contains("scalar|batched|reference|lut"), "{err}");
    let policy = Policy::parse("on overload do backend lut").unwrap();
    let err = match Controller::new(handle(), bank(), policy) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("lut switch must fail at construction"),
    };
    assert!(err.contains("scalar|batched|reference"), "{err}");

    // A well-formed policy over the same bank still builds.
    let policy = Policy::parse(
        "on ddos-ramp do swap night\non imbalance do reshard 4\n\
         on overload do overflow drop\non latency-slo do backend scalar",
    )
    .unwrap();
    assert!(Controller::new(handle(), bank(), policy).is_ok());
}

/// C3: an incompatible bank artifact can be *proposed* by policy but
/// never published — the live model is undisturbed.
#[test]
fn c3_incompatible_artifact_rejected_without_disturbing_serving() {
    let live = prefix_classifier(0xC0A8_0000);
    // Different architecture (32 -> [16] vs 32 -> [1]): the deployment
    // must refuse the swap at publication time.
    let wrong_arch = BnnModel::random(32, &[16], 5);
    let dep = deployment_for(&live);
    let bank = ModelBank::new("day", live.clone()).with_model("bad", wrong_arch);
    let policy = Policy::parse("on ddos-ramp do swap bad cooldown=4").unwrap();
    let cfg = SimConfig { n_shards: 2, window_packets: 256, seed: 17 };
    let seq = ScenarioSequence::new(vec![
        (Scenario::Uniform, 512),
        (Scenario::DdosBurst { ddos: sim_ddos(), peak_fraction: 0.9 }, 2048),
    ]);
    let mut sim = Sim::new(&dep, "live", bank, policy, cfg).unwrap();
    let report = sim.run_sequence(&seq).unwrap();

    assert!(report.rejected_swaps >= 1, "\n{}", report.render());
    assert!(report.swaps.is_empty(), "nothing published");
    assert_eq!(dep.version("live").unwrap(), 1, "live model undisturbed");
    // Every packet was served by the (only) live model.
    let st = seq.generate(cfg.seed);
    for (i, &key) in st.trace.keys.iter().enumerate() {
        assert_eq!(report.outputs[i], expect_bit(&live, key), "pkt {i}");
    }
    // The rejection is visible in the event log.
    assert!(report
        .ticks
        .iter()
        .flat_map(|t| &t.events)
        .any(|e| e.render().contains("REJECTED")));
}
