//! E2 — Figure 2 reproduction: the five-step schedule of a 3-neuron BNN.
//!
//! The paper's figure shows: (1) Replication, (2) XNOR and Duplication,
//! (3) POPCNT as mask/sum element pairs, (4) SIGN, (5) Folding. This
//! test golden-checks the structure of the emitted schedule.

use n2net::bnn::BnnModel;
use n2net::compiler::{Compiler, CompilerOptions, InputEncoding};
use n2net::rmt::{ChipConfig, StepKind};

fn compile_fig2() -> n2net::compiler::CompiledModel {
    let model = BnnModel::random(32, &[3], 2018);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        ..Default::default()
    };
    Compiler::new(ChipConfig::rmt(), opts).compile(&model).unwrap()
}

#[test]
fn five_step_structure() {
    let compiled = compile_fig2();
    let steps: Vec<StepKind> = compiled.program.elements.iter().map(|e| e.step).collect();

    // Step 1: replication first (3 parallel neurons over 32b).
    assert_eq!(steps[0], StepKind::Replication);
    // Step 2: XNOR + duplication.
    assert_eq!(steps[1], StepKind::XnorDup);
    // Step 3: POPCNT = exactly 2·log2(32) = 10 elements, strictly
    // alternating mask/sum pairs ("combining two pipeline's elements").
    let popcnt: Vec<StepKind> = steps[2..12].to_vec();
    for (i, s) in popcnt.iter().enumerate() {
        let expect = if i % 2 == 0 { StepKind::PopcntMask } else { StepKind::PopcntSum };
        assert_eq!(*s, expect, "popcnt element {i}");
    }
    // Step 4 and 5.
    assert_eq!(steps[12], StepKind::Sign);
    assert_eq!(steps[13], StepKind::Fold);
    assert_eq!(steps.len(), 14); // Table 1 @32b

    // The XNOR element stores the result twice (duplication): it writes
    // 2 containers per replica = 6 micro-ops for 3 neurons.
    let xnor = &compiled.program.elements[1];
    assert_eq!(xnor.ops.len(), 6, "3 neurons × (A copy + B copy)");
}

#[test]
fn schedule_listing_names_paper_steps() {
    let compiled = compile_fig2();
    let listing = compiled.program.schedule_listing();
    for needle in ["Replication", "XNOR+Duplication", "POPCNT(mask)", "POPCNT(sum)", "SIGN", "Folding"] {
        assert!(listing.contains(needle), "missing {needle} in:\n{listing}");
    }
}

#[test]
fn fig2_model_output_has_three_bits() {
    let compiled = compile_fig2();
    assert_eq!(compiled.output_bits, 3);
    // The folding step produces one container holding the 3-bit Y vector.
    let fold = compiled.program.elements.last().unwrap();
    assert_eq!(fold.ops.len(), 1);
    assert_eq!(fold.ops[0].slot_cost(), 3); // one gathered bit per neuron
}
