//! Observability properties (ISSUE 9 acceptance):
//!
//! O1. `telemetry::Histogram` is lossless under concurrency: N threads
//!     recording into one shared histogram never lose a count, and
//!     merging per-thread histograms reproduces the shared one exactly
//!     (bucket counts AND the saturating nanosecond sum).
//! O2. `quantile_ns_from_buckets` is monotone in q for arbitrary
//!     bucket contents.
//! O3. Hot-path tracing is output-invariant: for any scenario, shard
//!     count, and sample rate, the sharded tier's outputs are bit-exact
//!     with the tracing-off oracle — the flight recorder observes
//!     frames, it never touches classification. Rate 0 records zero
//!     events and never even bumps the sampling ticket.

use std::sync::Arc;
use std::time::Duration;

use n2net::bnn::BnnModel;
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::net::Scenario;
use n2net::telemetry::{quantile_ns_from_buckets, Histogram};
use n2net::util::prop;
use n2net::util::rng::Rng;

fn check_histogram_concurrent_lossless(rng: &mut Rng) -> Result<(), String> {
    let n_threads = 2 + rng.gen_range(0, 4);
    let per_thread = 200 + rng.gen_range(0, 800);
    let shared = Arc::new(Histogram::new());
    let seed = rng.next_u64();
    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(seed ^ (t as u64).wrapping_mul(0x9E37));
                let local = Histogram::new();
                for _ in 0..per_thread {
                    // Spans every bucket including the clamped top one.
                    let ns = 1u64 << rng.gen_range(0, 50);
                    shared.record(Duration::from_nanos(ns));
                    local.record(Duration::from_nanos(ns));
                }
                local
            })
        })
        .collect();
    let merged = Histogram::new();
    for h in handles {
        merged.merge(&h.join().map_err(|_| "recorder thread panicked")?);
    }

    let expect = (n_threads * per_thread) as u64;
    if shared.count() != expect {
        return Err(format!(
            "shared histogram lost counts: {} of {expect}",
            shared.count()
        ));
    }
    if merged.count() != expect {
        return Err(format!(
            "merged histogram lost counts: {} of {expect}",
            merged.count()
        ));
    }
    if merged.bucket_counts() != shared.bucket_counts() {
        return Err(format!(
            "merge disagrees with concurrent record:\n merged {:?}\n shared {:?}",
            merged.bucket_counts(),
            shared.bucket_counts()
        ));
    }
    // Same multiset of samples, no saturation reachable here (≤ 6 * 1000
    // * 2^49 < u64::MAX), so the sums must agree exactly.
    if merged.sum_ns() != shared.sum_ns() {
        return Err(format!(
            "sum diverged: merged {} vs shared {}",
            merged.sum_ns(),
            shared.sum_ns()
        ));
    }
    Ok(())
}

#[test]
fn prop_o1_histogram_concurrent_record_and_merge_lose_nothing() {
    let cases = prop::default_cases().min(16);
    prop::check("histogram-lossless", cases, check_histogram_concurrent_lossless);
}

fn check_quantile_monotone(rng: &mut Rng) -> Result<(), String> {
    let mut buckets = vec![0u64; 48];
    for _ in 0..(1 + rng.gen_range(0, 10)) {
        let i = rng.gen_range(0, buckets.len());
        buckets[i] += (1 + rng.gen_range(0, 1000)) as u64;
    }
    let mut last = 0.0f64;
    for step in 0..=20 {
        let q = step as f64 / 20.0;
        let v = quantile_ns_from_buckets(&buckets, q);
        if v < last {
            return Err(format!(
                "quantile not monotone: q={q} gave {v} after {last} \
                 (buckets {buckets:?})"
            ));
        }
        last = v;
    }
    Ok(())
}

#[test]
fn prop_o2_quantile_is_monotone_in_q() {
    prop::check("quantile-monotone", prop::default_cases(), check_quantile_monotone);
}

fn scenario_for(rng: &mut Rng) -> Scenario {
    match rng.gen_range(0, 4) {
        0 => Scenario::Uniform,
        1 => Scenario::DdosBurst {
            ddos: Scenario::default_ddos(),
            peak_fraction: 0.5 + rng.gen_f64() * 0.4,
        },
        2 => Scenario::ZipfHeavyHitter {
            n_flows: 2 + rng.gen_range(0, 64),
            hitter_share: 0.2 + rng.gen_f64() * 0.4,
        },
        _ => Scenario::MalformedFuzz { malformed_share: rng.gen_f64() },
    }
}

fn check_tracing_is_output_invariant(rng: &mut Rng) -> Result<(), String> {
    let scenario = scenario_for(rng);
    let n_shards = 1 + rng.gen_range(0, 4);
    let layers = vec![1 + rng.gen_range(0, 16)];
    let model = BnnModel::random(32, &layers, rng.next_u64());
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .workers(2)
        .model("m", model)
        .build()
        .map_err(|e| format!("deploy 32b->{layers:?}: {e}"))?;
    let n = 100 + rng.gen_range(0, 400);
    let trace = scenario.generate(rng.next_u64(), n);

    // Oracle: the identical engine with tracing off (the default).
    let off = deployment
        .sharded_engine("m", n_shards)
        .map_err(|e| e.to_string())?;
    let oracle = off.process_trace(&trace.packets).map_err(|e| e.to_string())?;
    if off.tracer().recorded() != 0 || off.tracer().attempts() != 0 {
        return Err(format!(
            "disabled tracer touched state: recorded={} attempts={}",
            off.tracer().recorded(),
            off.tracer().attempts()
        ));
    }

    for rate in [1u64, 3, 64, 1 << 40] {
        let engine = deployment
            .sharded_engine("m", n_shards)
            .map_err(|e| e.to_string())?;
        engine.tracer().set_sample_rate(rate);
        let r = engine.process_trace(&trace.packets).map_err(|e| e.to_string())?;
        if r.outputs != oracle.outputs {
            let i = r
                .outputs
                .iter()
                .zip(&oracle.outputs)
                .position(|(a, b)| a != b)
                .unwrap();
            return Err(format!(
                "scenario {} rate {rate} diverged at pkt {i}: {:#x} vs {:#x}",
                scenario.name(),
                r.outputs[i],
                oracle.outputs[i]
            ));
        }
        if rate == 1 && engine.tracer().recorded() == 0 {
            return Err("full-rate tracing over a live run recorded nothing".into());
        }
    }
    Ok(())
}

#[test]
fn prop_o3_tracing_at_any_rate_is_output_invariant() {
    let cases = prop::default_cases().min(16);
    prop::check("tracing-invariant", cases, check_tracing_is_output_invariant);
}
