//! Cross-module integration: apps + engine + compiler + simulator
//! working together (no artifacts required — these use random models;
//! the artifact-dependent path is covered by `oracle_roundtrip.rs` and
//! `examples/e2e_pipeline.rs`).

use n2net::apps::{lb_hints::hash_route_report, DdosFilter, HintRouter};
use n2net::backend::BackendKind;
use n2net::bnn::io::{DdosDoc, SubnetDoc};
use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::compiler::{p4gen, Compiler, CompilerOptions, InputEncoding};
use n2net::coordinator::{Engine, EngineConfig, RouterPolicy};
use n2net::net::{TraceGenerator, TraceKind};
use n2net::rmt::ChipConfig;

fn test_ddos() -> DdosDoc {
    DdosDoc {
        subnets: vec![
            SubnetDoc { prefix: 0xC0A80000, prefix_len: 16 },
            SubnetDoc { prefix: 0x0A400000, prefix_len: 10 },
            SubnetDoc { prefix: 0xAC100000, prefix_len: 12 },
        ],
        attack_fraction: 0.5,
        seed: 77,
    }
}

#[test]
fn ddos_filter_agrees_with_reference_on_full_trace() {
    let model = BnnModel::random(32, &[64, 32, 1], 101);
    let ddos = test_ddos();
    let mut filter = DdosFilter::new(&model, ChipConfig::rmt(), ddos.clone()).unwrap();
    let mut gen = TraceGenerator::new(5);
    let trace = gen.generate(&TraceKind::Ddos { ddos }, 400);
    for (pkt, &key) in trace.packets.iter().zip(&trace.keys) {
        let pred = filter.classify_frame(pkt).unwrap();
        let expect = bnn::forward(&model, &PackedBits::from_u32(key)).get(0) as u32;
        assert_eq!(pred, expect);
    }
    assert_eq!(filter.pipeline_stats().packets, 400);
    assert_eq!(filter.pipeline_stats().parse_errors, 0);
}

#[test]
fn engine_matches_single_pipeline_across_routers() {
    let model = BnnModel::random(32, &[32, 16], 103);
    let mut gen = TraceGenerator::new(9);
    let trace = gen.generate(&TraceKind::UniformIps, 300);
    let opts = CompilerOptions {
        input: InputEncoding::BigEndianField {
            offset: n2net::net::packet::IPV4_SRC_OFFSET,
        },
        ..Default::default()
    };
    let mut reference: Option<Vec<u32>> = None;
    for (workers, router) in [
        (1, RouterPolicy::RoundRobin),
        (3, RouterPolicy::RoundRobin),
        (3, RouterPolicy::FlowHash),
    ] {
        for backend in [BackendKind::Scalar, BackendKind::Batched] {
            let compiled = Compiler::new(ChipConfig::rmt(), opts.clone())
                .compile(&model)
                .unwrap();
            let engine = Engine::new(
                compiled,
                EngineConfig { n_workers: workers, router, backend, ..Default::default() },
            );
            let report = engine.process_trace(&trace.packets).unwrap();
            match &reference {
                None => reference = Some(report.outputs),
                Some(r) => assert_eq!(
                    &report.outputs, r,
                    "workers={workers} router={router:?} backend={backend:?} \
                     changed outputs"
                ),
            }
        }
    }
}

#[test]
fn hint_router_and_hash_cover_all_queues() {
    let model = BnnModel::random(32, &[16], 107);
    let mut router = HintRouter::new(&model, ChipConfig::rmt(), 2).unwrap();
    let mut gen = TraceGenerator::new(21);
    let trace = gen.generate(&TraceKind::UniformIps, 2000);
    let rep = router.evaluate(&trace).unwrap();
    assert_eq!(rep.queue_counts.iter().sum::<usize>(), 2000);
    let hash = hash_route_report(&trace, 2);
    assert_eq!(hash.queue_counts.iter().sum::<usize>(), 2000);
}

#[test]
fn p4_output_is_complete_for_use_case_model() {
    let model = BnnModel::random(32, &[64, 32], 109);
    let compiled = Compiler::rmt().compile(&model).unwrap();
    let p4 = p4gen::render(&compiled.program, &compiled.parser, "usecase");
    // One action per element, one table per weight-carrying element.
    assert_eq!(p4.matches("action e").count(), 30);
    assert_eq!(p4.matches("table tbl_").count(), 2); // one XNOR table/layer
    assert!(p4.contains("apply"));
}

#[test]
fn recirculation_path_still_correct() {
    // A deep model (> 32 elements) exercises multi-pass semantics.
    let model = BnnModel::random(32, &[64, 32, 32, 16], 113);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts).compile(&model).unwrap();
    assert!(compiled.resources.passes > 1, "model should recirculate");
    let mut pipe = n2net::rmt::Pipeline::new(
        ChipConfig::rmt(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let mut rng = n2net::util::rng::Rng::seed_from_u64(3);
    for _ in 0..10 {
        let x = PackedBits::random(32, &mut rng);
        let mut pkt = Vec::new();
        for w in x.words() {
            pkt.extend_from_slice(&w.to_le_bytes());
        }
        let phv = pipe.process_packet(&pkt).unwrap();
        assert_eq!(compiled.read_output(&phv), bnn::forward(&model, &x));
    }
    // And the throughput model reflects the pass count.
    let t = pipe.timing();
    assert_eq!(t.pps, 960e6 / t.passes as f64);
}

#[test]
fn oversized_model_is_graceful_error_without_recirculation() {
    let model = BnnModel::random(32, &[64, 32, 32, 16], 115);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        allow_recirculation: false,
        ..Default::default()
    };
    let msg = match Compiler::new(ChipConfig::rmt(), opts).compile(&model) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("oversized model compiled without recirculation"),
    };
    assert!(msg.contains("elements"), "unexpected error: {msg}");
}

#[test]
fn malformed_traffic_never_panics_the_engine() {
    let model = BnnModel::random(32, &[16], 117);
    let opts = CompilerOptions {
        input: InputEncoding::BigEndianField {
            offset: n2net::net::packet::IPV4_SRC_OFFSET,
        },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts).compile(&model).unwrap();
    let engine = Engine::new(
        compiled,
        EngineConfig {
            n_workers: 2,
            router: RouterPolicy::RoundRobin,
            ..Default::default()
        },
    );
    // Garbage of every length 0..64.
    let packets: Vec<Vec<u8>> = (0..64usize).map(|n| vec![0xAA; n]).collect();
    let report = engine.process_trace(&packets).unwrap();
    assert_eq!(report.outputs.len(), 64);
    assert!(engine.metrics.packets_dropped.get() > 0);
}
