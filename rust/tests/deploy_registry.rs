//! Deployment-registry integration tests (ISSUE 2): the keyed-table
//! multi-model path — [`Compiler::compile_multi`] reached through its
//! first public entry point, `Deployment::builder().keyed(..)` — and
//! the isolated multi-model registry.
//!
//! Packet format for keyed deployments here:
//! `[model id u32 LE][activation words LE]` with the activation parsed
//! from offset 4 and the id matched at offset 0.

use n2net::backend::BackendKind;
use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::util::rng::Rng;

fn frame(id: u32, x: &PackedBits) -> Vec<u8> {
    let mut pkt = id.to_le_bytes().to_vec();
    for w in x.words() {
        pkt.extend_from_slice(&w.to_le_bytes());
    }
    pkt
}

fn keyed_two_model_deployment() -> (BnnModel, BnnModel, Deployment) {
    let model_a = BnnModel::random(32, &[32, 16], 100);
    let model_b = BnnModel::random(32, &[32, 16], 200);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 4 })
        .keyed(0)
        .model_with_id("alpha", 7, model_a.clone())
        .model_with_id("beta", 13, model_b.clone())
        .build()
        .unwrap();
    (model_a, model_b, deployment)
}

/// Two models behind keyed tables: every packet's output is bit-exact
/// with the model its id selects, and never leaks the other model's
/// weights (per-model output isolation).
#[test]
fn keyed_registry_isolates_per_model_outputs() {
    let (model_a, model_b, deployment) = keyed_two_model_deployment();
    let mut session = deployment.keyed_session().unwrap();
    let mask = n2net::backend::out_mask(16);
    let mut rng = Rng::seed_from_u64(1);
    for round in 0..30 {
        let x = PackedBits::random(32, &mut rng);
        let expect_a = bnn::forward(&model_a, &x).words()[0] & mask;
        let expect_b = bnn::forward(&model_b, &x).words()[0] & mask;
        let pkts = vec![frame(7, &x), frame(13, &x)];
        let refs: Vec<&[u8]> = pkts.iter().map(|p| p.as_slice()).collect();
        let mut out = Vec::new();
        session.classify_batch(&refs, &mut out).unwrap();
        assert_eq!(out[0], expect_a, "round {round}: id 7 must serve alpha");
        assert_eq!(out[1], expect_b, "round {round}: id 13 must serve beta");
        if expect_a != expect_b {
            assert_ne!(out[0], out[1], "round {round}: outputs must not blend");
        }
    }
    // Attribution: 30 packets each.
    assert_eq!(deployment.stats("alpha").unwrap().packets, 30);
    assert_eq!(deployment.stats("beta").unwrap().packets, 30);
}

#[test]
fn keyed_registry_unknown_id_serves_the_default_model() {
    let (model_a, _, deployment) = keyed_two_model_deployment();
    let mut session = deployment.keyed_session().unwrap();
    let mask = n2net::backend::out_mask(16);
    let mut rng = Rng::seed_from_u64(2);
    let x = PackedBits::random(32, &mut rng);
    let pkt = frame(0xFFFF_FFFF, &x);
    let refs: Vec<&[u8]> = vec![&pkt];
    let mut out = Vec::new();
    session.classify_batch(&refs, &mut out).unwrap();
    // Table miss -> default action data = the first registered model.
    assert_eq!(out[0], bnn::forward(&model_a, &x).words()[0] & mask);
    // Attribution follows the same miss rule.
    assert_eq!(deployment.stats("alpha").unwrap().packets, 1);
    assert_eq!(deployment.stats("beta").unwrap().packets, 0);
}

/// The keyed program serves mixed streams through the multi-worker
/// engine too, preserving per-packet model selection and input order.
#[test]
fn keyed_registry_engine_serves_mixed_streams() {
    let (model_a, model_b, deployment) = keyed_two_model_deployment();
    let mask = n2net::backend::out_mask(16);
    let mut rng = Rng::seed_from_u64(3);
    let mut packets = Vec::new();
    let mut expects = Vec::new();
    for i in 0..200 {
        let x = PackedBits::random(32, &mut rng);
        let (id, model) = if i % 3 == 0 { (13, &model_b) } else { (7, &model_a) };
        packets.push(frame(id, &x));
        expects.push(bnn::forward(model, &x).words()[0] & mask);
    }
    let report = deployment.serve_trace_keyed(&packets).unwrap();
    assert_eq!(report.outputs.len(), 200);
    assert_eq!(report.model_version, 1);
    for (i, (&got, &expect)) in report.outputs.iter().zip(&expects).enumerate() {
        assert_eq!(got, expect, "pkt {i}");
    }
}

/// Hot-swapping one entry of a keyed deployment republishes the shared
/// program: the swapped tenant serves the new weights, the other tenant
/// is untouched, and the version counter moves once.
#[test]
fn keyed_registry_swap_republishes_one_tenant() {
    let (model_a, _, deployment) = keyed_two_model_deployment();
    let retrained = BnnModel::random(32, &[32, 16], 999);
    let v = deployment.swap_model("beta", retrained.clone()).unwrap();
    assert_eq!(v, 2);
    assert_eq!(deployment.version("alpha").unwrap(), 2, "shared program version");
    let mut session = deployment.keyed_session().unwrap();
    let mask = n2net::backend::out_mask(16);
    let mut rng = Rng::seed_from_u64(4);
    let x = PackedBits::random(32, &mut rng);
    let pkts = vec![frame(7, &x), frame(13, &x)];
    let refs: Vec<&[u8]> = pkts.iter().map(|p| p.as_slice()).collect();
    let mut out = Vec::new();
    assert_eq!(session.classify_batch(&refs, &mut out).unwrap(), 2);
    assert_eq!(out[0], bnn::forward(&model_a, &x).words()[0] & mask, "alpha untouched");
    assert_eq!(out[1], bnn::forward(&retrained, &x).words()[0] & mask, "beta retrained");
}

/// Isolated (non-keyed) registries compile one program per model; the
/// sessions are fully independent.
#[test]
fn isolated_registry_runs_models_independently() {
    let model_a = BnnModel::random(32, &[16, 1], 5);
    let model_b = BnnModel::random(32, &[16, 1], 6);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 0 })
        .backend(BackendKind::Batched)
        .model("a", model_a.clone())
        .model("b", model_b.clone())
        .build()
        .unwrap();
    assert_eq!(deployment.models(), vec!["a", "b"]);
    let mut sa = deployment.session("a").unwrap();
    let mut sb = deployment.session("b").unwrap();
    let mut rng = Rng::seed_from_u64(7);
    for _ in 0..20 {
        let x = PackedBits::random(32, &mut rng);
        let mut pkt = Vec::new();
        for w in x.words() {
            pkt.extend_from_slice(&w.to_le_bytes());
        }
        let refs: Vec<&[u8]> = vec![&pkt];
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        sa.classify_batch(&refs, &mut oa).unwrap();
        sb.classify_batch(&refs, &mut ob).unwrap();
        assert_eq!(oa[0] & 1, bnn::forward(&model_a, &x).get(0) as u32);
        assert_eq!(ob[0] & 1, bnn::forward(&model_b, &x).get(0) as u32);
    }
    assert_eq!(deployment.stats("a").unwrap().packets, 20);
    assert_eq!(deployment.stats("b").unwrap().packets, 20);
}

/// The keyed program costs SRAM entries, not pipeline stages, and the
/// deployment exposes that through its compiled-program accessor.
#[test]
fn keyed_registry_costs_sram_not_stages() {
    let (_, _, deployment) = keyed_two_model_deployment();
    let single = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 4 })
        .model("solo", BnnModel::random(32, &[32, 16], 100))
        .build()
        .unwrap();
    let keyed = deployment.compiled("alpha").unwrap();
    let solo = single.compiled("solo").unwrap();
    assert_eq!(keyed.program.n_elements(), solo.program.n_elements());
    assert!(
        keyed.resources.sram_bits > solo.resources.sram_bits,
        "2 keyed models must cost more table SRAM than 1: {} vs {}",
        keyed.resources.sram_bits,
        solo.resources.sram_bits
    );
}
