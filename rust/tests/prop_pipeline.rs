//! Property tests (crate-local `util::prop` driver, see DESIGN.md
//! §Substitutions) — the crate's central invariants:
//!
//! P1. For ANY valid model and input, the compiled switch pipeline's
//!     output equals the trusted reference forward, bit for bit.
//! P2. Every emitted program passes all legality checks (write-once per
//!     container, ≤224 op slots, ≤32 elements per pass, SRAM budget).
//! P3. Emitted element counts equal the closed-form Table 1 accounting.
//! P4. The POPCNT tree schedule equals `u32::count_ones` composition.
//! P5. Parser round-trips packed activation encodings.
//! P6. The native-POPCNT variant agrees with the stock variant.

use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::compiler::popcount::tree_reference;
use n2net::compiler::{Compiler, CompilerOptions, InputEncoding};
use n2net::net::packet::PacketBuilder;
use n2net::rmt::{ChipConfig, Pipeline};
use n2net::util::prop::{self, pow2_in};
use n2net::util::rng::Rng;

/// Random valid *and feasible* BNN spec, biased small for speed but
/// covering the full architectural range.
///
/// Feasibility caveat (a real architectural limit the compiler reports
/// as `ResourceExhausted`): a 2048-bit activation layer with more than
/// one neuron cannot run multi-round on the stock chip, because the
/// activation plus its duplicate fill the entire PHV and leave no room
/// to preserve the source between rounds. The paper only ever runs one
/// 2048-bit neuron (Table 1), and so does this generator.
fn random_spec(rng: &mut Rng) -> (usize, Vec<usize>) {
    let in_bits = pow2_in(rng, 16, 2048);
    if in_bits == 2048 {
        return (in_bits, vec![1]);
    }
    let n_layers = 1 + rng.gen_range(0, 3);
    let mut layers = Vec::new();
    for i in 0..n_layers {
        if i + 1 == n_layers {
            // Final layer: any size ≥ 1 (classifier heads are odd); capped
            // so very wide first activations stay multi-round-feasible.
            let cap = if in_bits >= 512 && i == 0 { 8 } else { 48 };
            layers.push(1 + rng.gen_range(0, cap));
        } else {
            layers.push(pow2_in(rng, 16, 128));
        }
    }
    (in_bits, layers)
}

fn frame_for(x: &PackedBits) -> Vec<u8> {
    let mut pkt = Vec::with_capacity(x.words().len() * 4);
    for w in x.words() {
        pkt.extend_from_slice(&w.to_le_bytes());
    }
    pkt
}

fn check_equivalence(chip: ChipConfig, rng: &mut Rng) -> Result<(), String> {
    let (in_bits, layers) = random_spec(rng);
    let seed = rng.next_u64();
    let model = BnnModel::random(in_bits, &layers, seed);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        weights_as_immediates: rng.gen_bool(0.5),
        ..Default::default()
    };
    let compiled = Compiler::new(chip.clone(), opts)
        .compile(&model)
        .map_err(|e| format!("compile {in_bits}b->{layers:?}: {e}"))?;
    // P2: legality (recirculation allowed).
    compiled
        .program
        .validate(&chip, true)
        .map_err(|e| format!("legality: {e}"))?;
    // P3: plan vs emitted count.
    if compiled.program.n_elements() != compiled.layout.total_elements {
        return Err(format!(
            "element count: emitted {} != planned {}",
            compiled.program.n_elements(),
            compiled.layout.total_elements
        ));
    }
    // P1: bit-exact equivalence on random inputs.
    let mut pipe = Pipeline::new(
        chip,
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .map_err(|e| e.to_string())?;
    for _ in 0..4 {
        let x = PackedBits::random(in_bits, rng);
        let phv = pipe
            .process_packet(&frame_for(&x))
            .map_err(|e| e.to_string())?;
        let got = compiled.read_output(&phv);
        let expect = bnn::forward(&model, &x);
        if got != expect {
            return Err(format!(
                "mismatch for {in_bits}b->{layers:?} seed {seed:#x} input {x:?}: \
                 got {got:?} expect {expect:?}"
            ));
        }
    }
    Ok(())
}

#[test]
fn p1_p2_p3_pipeline_equals_reference_stock_chip() {
    prop::check("pipeline≡reference/stock", prop::default_cases(), |rng| {
        check_equivalence(ChipConfig::rmt(), rng)
    });
}

#[test]
fn p6_pipeline_equals_reference_native_popcnt_chip() {
    prop::check("pipeline≡reference/native", prop::default_cases(), |rng| {
        check_equivalence(ChipConfig::rmt_with_popcnt(), rng)
    });
}

#[test]
fn p4_popcount_tree_equals_count_ones() {
    prop::check("popcnt-tree≡count_ones", 256, |rng| {
        let n_bits = pow2_in(rng, 16, 2048);
        let v = PackedBits::random(n_bits, rng);
        let got = tree_reference(v.words(), n_bits);
        let expect = v.popcount();
        if got == expect {
            Ok(())
        } else {
            Err(format!("n_bits={n_bits}: tree {got} != popcount {expect}"))
        }
    });
}

#[test]
fn p5_parser_roundtrips_payload_encoding() {
    prop::check("parser-roundtrip", 128, |rng| {
        let n_bits = pow2_in(rng, 16, 2048);
        let x = PackedBits::random(n_bits, rng);
        let frame = PacketBuilder::default().build_activations(x.words());
        // Parse back from the frame at the N2Net payload offset.
        let off = n2net::net::N2NET_PAYLOAD_OFFSET;
        let mut words = Vec::new();
        for k in 0..x.words().len() {
            let b = &frame[off + 4 * k..off + 4 * k + 4];
            words.push(u32::from_le_bytes(b.try_into().unwrap()));
        }
        if PackedBits::from_words(words, n_bits) == x {
            Ok(())
        } else {
            Err(format!("payload roundtrip failed for {n_bits} bits"))
        }
    });
}

#[test]
fn p2_programs_never_exceed_budgets() {
    prop::check("op-budget", 64, |rng| {
        let (in_bits, layers) = random_spec(rng);
        let model = BnnModel::random(in_bits, &layers, rng.next_u64());
        let chip = ChipConfig::rmt();
        let compiled = Compiler::new(chip.clone(), CompilerOptions::default())
            .compile(&model)
            .map_err(|e| e.to_string())?;
        for (i, e) in compiled.program.elements.iter().enumerate() {
            let cost = e.slot_cost();
            if cost > chip.max_ops_per_element {
                return Err(format!("element {i} uses {cost} slots"));
            }
            if e.sram_bits(&chip.phv) > chip.sram_bits_per_element {
                return Err(format!("element {i} exceeds SRAM"));
            }
        }
        Ok(())
    });
}

#[test]
fn multi_packet_statelessness() {
    // Processing a packet must not leak state into the next: same input
    // always gives the same output regardless of history.
    prop::check("stateless", 32, |rng| {
        let model = BnnModel::random(32, &[32, 16], rng.next_u64());
        let compiled = Compiler::new(
            ChipConfig::rmt(),
            CompilerOptions {
                input: InputEncoding::PayloadLe { offset: 0 },
                ..Default::default()
            },
        )
        .compile(&model)
        .map_err(|e| e.to_string())?;
        let mut pipe = Pipeline::new(
            ChipConfig::rmt(),
            compiled.program.clone(),
            compiled.parser.clone(),
            true,
        )
        .map_err(|e| e.to_string())?;
        let probe = PackedBits::random(32, rng);
        let first = compiled.read_output(
            &pipe.process_packet(&frame_for(&probe)).map_err(|e| e.to_string())?,
        );
        for _ in 0..8 {
            let noise = PackedBits::random(32, rng);
            pipe.process_packet(&frame_for(&noise)).map_err(|e| e.to_string())?;
            let again = compiled.read_output(
                &pipe.process_packet(&frame_for(&probe)).map_err(|e| e.to_string())?,
            );
            if again != first {
                return Err("pipeline leaked state between packets".into());
            }
        }
        Ok(())
    });
}
