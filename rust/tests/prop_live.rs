//! Live-reconfiguration properties (ISSUE 5 acceptance):
//!
//! L1. A mid-stream reshard and/or overflow flip under adversarial
//!     traffic (`ddos-burst`, `malformed-fuzz`) loses NO frame under
//!     [`OverflowPolicy::Block`]: every pushed frame is classified and
//!     the merged outputs are bit-exact, frame for frame, with the
//!     single-engine oracle on the same trace.
//! L2. Under [`OverflowPolicy::Drop`], every shed frame is accounted:
//!     delivered + dropped == pushed, a shed frame's output word is
//!     pinned 0, and every DELIVERED frame is still bit-exact with the
//!     oracle (outputs differ from the oracle only where a frame was
//!     shed — never a fabricated prediction).
//! L3. A mid-stream backend switch (batched ↔ scalar) changes no output
//!     at all — backends are bit-exact on the same artifact and the
//!     switch lands only at batch boundaries.
//!
//! The per-flow old-or-new guarantee is the drain-and-rebuild barrier:
//! the old tier finishes every queued frame before the new tier sees
//! one, so bit-exactness of the concatenated epochs (checked here)
//! subsumes "never interleaved".

use std::sync::Arc;

use n2net::backend::BackendKind;
use n2net::bnn::BnnModel;
use n2net::compiler::{Compiler, CompilerOptions, InputEncoding};
use n2net::controlplane::sim_ddos;
use n2net::coordinator::{OverflowPolicy, ShardConfig, ShardedEngine};
use n2net::net::packet::IPV4_SRC_OFFSET;
use n2net::net::{Scenario, ScenarioSequence};
use n2net::rmt::ChipConfig;
use n2net::util::prop;
use n2net::util::rng::Rng;

fn engine_for(model: &BnnModel, config: ShardConfig) -> ShardedEngine {
    let opts = CompilerOptions {
        input: InputEncoding::BigEndianField { offset: IPV4_SRC_OFFSET },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts).compile(model).unwrap();
    ShardedEngine::new(compiled, config).with_model(model.clone())
}

/// The single-engine oracle: the same trace through ONE lossless shard
/// with no reconfiguration — exactly what every delivered frame of the
/// reconfigured run must agree with.
fn oracle_outputs(model: &BnnModel, packets: &[Vec<u8>]) -> Vec<u32> {
    engine_for(model, ShardConfig { n_shards: 1, ..ShardConfig::default() })
        .process_trace(packets)
        .unwrap()
        .outputs
}

/// One random live-reconfiguration run: an adversarial sequence served
/// through a LiveStream with a reshard, an overflow flip, and a backend
/// switch injected at random frame positions.
fn check_live_reconfig(rng: &mut Rng) -> Result<(), String> {
    let seed = rng.next_u64();
    let n_before = 1 + rng.gen_range(0, 3); // 1..=3 shards
    let n_after = 1 + rng.gen_range(0, 4); // 1..=4 shards
    let start_drop = rng.gen_bool(0.5);
    let flip_overflow = rng.gen_bool(0.5);
    let switch_backend = rng.gen_bool(0.5);
    // Small queues make Drop sheds likely (never guaranteed — the
    // accounting identity is what is asserted).
    let queue_capacity = if start_drop { 1 + rng.gen_range(0, 4) } else { 4096 };

    let seq = ScenarioSequence::new(vec![
        (Scenario::DdosBurst { ddos: sim_ddos(), peak_fraction: 0.9 }, 512),
        (Scenario::MalformedFuzz { malformed_share: 0.5 }, 512),
        (Scenario::Uniform, 256),
    ]);
    let st = seq.generate(seed);
    let n = st.trace.packets.len();
    let reshard_at = 64 + rng.gen_range(0, n - 128);
    let flip_at = 64 + rng.gen_range(0, n - 128);
    let switch_at = 64 + rng.gen_range(0, n - 128);

    let model = BnnModel::random(32, &[16, 1], seed ^ 0x11);
    let overflow =
        if start_drop { OverflowPolicy::Drop } else { OverflowPolicy::Block };
    let engine = Arc::new(engine_for(
        &model,
        ShardConfig {
            n_shards: n_before,
            queue_capacity,
            overflow,
            ..ShardConfig::default()
        },
    ));

    let mut stream = engine.live_stream().map_err(|e| e.to_string())?;
    for (i, pkt) in st.trace.packets.iter().enumerate() {
        if i == reshard_at {
            engine.reshard(n_after).map_err(|e| e.to_string())?;
        }
        if flip_overflow && i == flip_at {
            // Flip to the OTHER policy mid-stream.
            engine.set_overflow(match engine.overflow() {
                OverflowPolicy::Block => OverflowPolicy::Drop,
                OverflowPolicy::Drop => OverflowPolicy::Block,
            });
        }
        if switch_backend && i == switch_at {
            engine.set_backend(BackendKind::Scalar).map_err(|e| e.to_string())?;
        }
        stream.push(pkt.clone()).map_err(|e| e.to_string())?;
    }
    let report = stream.finish().map_err(|e| e.to_string())?;

    if report.n_packets != n || report.outputs.len() != n {
        return Err(format!(
            "{} of {n} outputs (epochs {})",
            report.outputs.len(),
            report.epochs.len()
        ));
    }
    if report.reconfigs() != 1 {
        return Err(format!("expected 1 reshard epoch, got {}", report.reconfigs()));
    }

    // Exact accounting: every frame delivered or counted as shed.
    let delivered = report.delivered();
    if delivered + report.dropped != n as u64 {
        return Err(format!(
            "delivered {delivered} + dropped {} != pushed {n}",
            report.dropped
        ));
    }
    let never_dropping = !start_drop && !flip_overflow;
    if never_dropping && report.dropped != 0 {
        return Err(format!("Block-only run shed {} frames", report.dropped));
    }

    // Per-frame oracle: Block-delivered frames are bit-exact; a
    // mismatch is only legal where a frame could have been shed, and
    // a shed frame's output is pinned 0.
    let oracle = oracle_outputs(&model, &st.trace.packets);
    let mut mismatches = 0u64;
    for (i, &expect) in oracle.iter().enumerate() {
        let got = report.outputs[i];
        if got == expect {
            continue;
        }
        if got != 0 {
            return Err(format!(
                "pkt {i}: served {got}, oracle {expect} — fabricated output"
            ));
        }
        mismatches += 1;
    }
    if mismatches > report.dropped {
        return Err(format!(
            "{mismatches} zeroed outputs but only {} shed frames",
            report.dropped
        ));
    }
    if never_dropping && mismatches != 0 {
        return Err(format!("lossless run lost {mismatches} frames"));
    }
    Ok(())
}

#[test]
fn prop_l1_l3_mid_stream_reconfiguration_is_lossless_and_accounted() {
    let cases = prop::default_cases().min(16);
    prop::check("live-reconfig", cases, check_live_reconfig);
}

/// The L1 corner pinned down deterministically: reshard exactly at a
/// segment boundary of an adversarial sequence under Block — zero
/// drops, bit-exact everywhere, flow-affinity preserved per epoch.
#[test]
fn reshard_at_segment_boundary_is_bit_exact_under_block() {
    let model = BnnModel::random(32, &[16, 1], 77);
    let engine = Arc::new(engine_for(
        &model,
        ShardConfig { n_shards: 2, ..ShardConfig::default() },
    ));
    let seq = ScenarioSequence::new(vec![
        (Scenario::DdosBurst { ddos: sim_ddos(), peak_fraction: 0.9 }, 512),
        (Scenario::MalformedFuzz { malformed_share: 0.5 }, 512),
    ]);
    let st = seq.generate(13);
    let mut stream = engine.live_stream().unwrap();
    for (i, pkt) in st.trace.packets.iter().enumerate() {
        if i == 512 {
            engine.reshard(4).unwrap();
        }
        stream.push(pkt.clone()).unwrap();
    }
    let report = stream.finish().unwrap();
    assert_eq!(report.dropped, 0);
    assert_eq!(report.epochs.len(), 2);
    assert_eq!(report.epochs[0].n_packets, 512);
    assert_eq!(report.epochs[1].per_shard.len(), 4);
    assert!(
        report.epochs[1].parse_errors > 0,
        "the fuzz segment exercises the parse-error lanes post-reshard"
    );
    let oracle = oracle_outputs(&model, &st.trace.packets);
    assert_eq!(report.outputs, oracle, "bit-exact with the single-engine run");
}
