//! Observability overhead bench (DESIGN.md §18) — the sharded hot path
//! with the flight recorder off, sampled, and at full rate, plus the
//! per-call cost of a disabled `record()`.
//!
//! The acceptance bar (ISSUE 9): tracing-off overhead on the sharded
//! hot path must stay ≤ 1%. "Off" is the shipped default — the only
//! cost a disabled tracer adds per event site is one relaxed atomic
//! load, which the `record-disabled` micro case prices directly
//! (sub-nanosecond per call, orders of magnitude under the per-packet
//! classify work the macro cases measure).
//!
//! Emits machine-readable records to `BENCH_obs.json` (`case` carries
//! the sampling configuration) alongside the overhead summary.
//!
//! `cargo bench --bench obs`

use n2net::bnn::BnnModel;
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::net::Scenario;
use n2net::obs::{EventKind, Tracer};
use n2net::util::bench::{
    default_bencher, keep, write_bench_json, BenchRecord, Report,
};

const BENCH_JSON: &str = "BENCH_obs.json";
/// Same sizing rationale as the shard bench: large enough that worker
/// spawn/teardown amortizes to noise, so off-vs-sampled deltas reflect
/// steady-state per-packet cost.
const N_PACKETS: usize = 16384;
const SHARDS: usize = 4;
/// Per-shard batch bound (the deployment default); the sampling
/// configuration rides in each record's `case` string.
const BATCH_SIZE: usize = 256;
/// Disabled-`record()` micro-case call count.
const N_CALLS: usize = 1 << 20;

fn main() {
    let model = BnnModel::random(32, &[64, 32], 3);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .model("obs-bench", model)
        .build()
        .unwrap();
    let trace = Scenario::parse("uniform").unwrap().generate(7, N_PACKETS);

    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut report = Report::new("observability — sharded hot path vs tracing");
    report.header();

    // Macro: the full sharded pipeline (ingress → dispatch → backend)
    // under the three sampling configurations.
    let mut rates: Vec<(&str, f64)> = Vec::new();
    for (case, rate) in
        [("tracing-off", 0u64), ("sampled-1in64", 64), ("full-rate", 1)]
    {
        let engine = deployment.sharded_engine("obs-bench", SHARDS).unwrap();
        engine.tracer().set_sample_rate(rate);
        let stats = b.run(
            &format!("{case} shards={SHARDS}"),
            N_PACKETS as f64,
            || {
                let r = engine.process_trace(&trace.packets).unwrap();
                keep(r.outputs.len());
            },
        );
        rates.push((case, stats.items_per_sec()));
        records.push(BenchRecord::from_stats("obs", "batched", BATCH_SIZE, &stats));
        report.add(stats);
    }

    // Micro: what one event site costs when tracing is off — the price
    // every packet pays for the flight recorder existing at all.
    let tracer = Tracer::for_shards(SHARDS);
    let stats = b.run("record-disabled", N_CALLS as f64, || {
        for i in 0..N_CALLS as u64 {
            tracer.record(i as usize & 3, EventKind::FrameIngress, i, 64);
        }
        keep(tracer.recorded());
    });
    records.push(BenchRecord::from_stats("obs", "batched", BATCH_SIZE, &stats));
    report.add(stats);

    let base = rates[0].1;
    println!("\noverhead vs tracing-off (aggregate pps, same trace):");
    for &(case, pps) in rates.iter().skip(1) {
        if pps > 0.0 {
            println!("  {case}: {:+.2}%", (base / pps - 1.0) * 100.0);
        }
    }
    println!(
        "target (ISSUE 9): tracing-off adds ≤1% — the off path is one \
         relaxed atomic load per event site (see record-disabled)"
    );

    match write_bench_json(BENCH_JSON, "obs", &records) {
        Ok(()) => println!("wrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
