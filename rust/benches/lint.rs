//! Policy-lint bench: what the pre-flight gate costs (ISSUE 10
//! satellite). `serve --adaptive` / `autopilot` run the full static
//! analysis of DESIGN.md §19 — state-graph exploration, cycle pruning,
//! shadowing, target legality, SLO sanity — before the controller
//! exists, so its cost bounds how fast an operator can iterate on a
//! policy file mid-incident. Measured here: lint cost vs policy size
//! (rule count, with a matching bank so every swap target resolves).
//!
//! Appends machine-readable records to `BENCH_lint.json`.
//!
//! `cargo bench --bench lint`

use n2net::backend::BackendKind;
use n2net::bnn::BnnModel;
use n2net::controlplane::{Linter, ModelBank, Policy};
use n2net::util::bench::{default_bencher, write_bench_json, BenchRecord, Report};

const BENCH_JSON: &str = "BENCH_lint.json";

/// A policy of `n` rules cycling through every action shape, plus a
/// bank registering each named swap target (same architecture, so no
/// legality findings distort the measurement toward error paths).
fn synth(n: usize) -> (Policy, ModelBank) {
    let day = BnnModel::random(32, &[64, 32], 1);
    let mut bank = ModelBank::new("day", day.clone());
    let mut text = String::new();
    for i in 0..n {
        match i % 5 {
            0 => {
                let name = format!("candidate-{i}");
                bank = bank.with_model(
                    &name,
                    BnnModel::random(32, &[64, 32], 100 + i as u64),
                );
                text.push_str(&format!(
                    "on ddos-ramp do swap {name} cooldown={} min-severity=0.{}\n",
                    2 + i % 7,
                    1 + i % 8
                ));
            }
            1 => text.push_str(&format!(
                "on overload do overflow {} cooldown={}\n",
                if i % 2 == 0 { "drop" } else { "block" },
                2 + i % 5
            )),
            2 => text.push_str(&format!(
                "on imbalance do reshard {} cooldown=6 min-severity=1.{}\n",
                2 + i % 8,
                i % 9
            )),
            3 => text.push_str("on latency-slo do alert cooldown=8\n"),
            _ => text.push_str("on drift do fallback cooldown=8\n"),
        }
    }
    (Policy::parse(&text).expect("synthetic policy parses"), bank)
}

fn main() {
    println!("# lint — static policy analysis cost vs policy size");
    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut report = Report::new("policy lint (per full analysis)");
    report.header();

    for n in [5usize, 20, 80] {
        let (policy, bank) = synth(n);
        let day_spec = bank.default_model().spec.clone();
        let stats = b.run(&format!("lint {n} rules"), 1.0, || {
            let report = Linter::new(&policy)
                .with_bank(&bank)
                .with_deployed(&day_spec)
                .with_tier_shape(2, BackendKind::Batched)
                .lint();
            std::hint::black_box(report.findings.len());
        });
        records.push(BenchRecord::from_stats(
            "lint",
            &format!("lint_rules_{n}"),
            n as u64,
            &stats,
        ));
        report.add(stats);
    }

    match write_bench_json(BENCH_JSON, "lint", &records) {
        Ok(()) => println!("\nwrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
