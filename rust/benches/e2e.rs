//! E4/E9 bench: end-to-end engine throughput on the DDoS workload —
//! the two-layer use-case model deployed through
//! [`n2net::deploy::Deployment`] and served by the multi-worker engine
//! on the scalar pipeline and the batched SoA tape.
//!
//! `cargo bench --bench e2e`

use n2net::backend::BackendKind;
use n2net::bnn::BnnModel;
use n2net::coordinator::{Batch, BatchPolicy, Batcher, RouterPolicy};
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::net::{TraceGenerator, TraceKind};
use n2net::util::bench::{default_bencher, format_rate, keep, Report};

fn main() {
    println!("# E4/E9 — end-to-end engine throughput (via deploy::Deployment)");
    // The paper's use-case model (+1-bit head for classification).
    let model = BnnModel::random(32, &[64, 32, 1], 2024);

    let mut gen = TraceGenerator::new(8);
    let ddos = n2net::bnn::io::DdosDoc {
        subnets: vec![n2net::bnn::io::SubnetDoc { prefix: 0xC0A80000, prefix_len: 16 }],
        attack_fraction: 0.5,
        seed: 2,
    };
    let trace = gen.generate(&TraceKind::Ddos { ddos }, 8192);

    let b = default_bencher();
    let mut report = Report::new("engine trace throughput (8192-packet trace per iter)");
    report.header();
    for backend in [BackendKind::Scalar, BackendKind::Batched] {
        for workers in [1usize, 2, 4] {
            let deployment = Deployment::builder()
                .extractor(FieldExtractor::SrcIp)
                .backend(backend)
                .workers(workers)
                .router(RouterPolicy::RoundRobin)
                .model("e2e", model.clone())
                .build()
                .unwrap();
            let engine = deployment.engine("e2e").unwrap();
            let stats = b.run(
                &format!("{} workers={workers}", backend.name()),
                trace.packets.len() as f64,
                || {
                    keep(engine.process_trace(&trace.packets).unwrap());
                },
            );
            println!("    -> sustained {}", format_rate(stats.items_per_sec()));
            report.add(stats);
        }
    }

    // Modeled ASIC for the same program.
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .model("e2e", model.clone())
        .build()
        .unwrap();
    let compiled = deployment.compiled("e2e").unwrap();
    let t = compiled.chip.timing(&compiled.program);
    println!(
        "\nmodeled ASIC for this program: {:.0} M packets/s ({} elements, {} pass)",
        t.pps / 1e6,
        t.elements,
        t.passes
    );

    // Batcher policy sensitivity (size bound only; the simulator is
    // offline so deadlines don't trigger).
    let mut report = Report::new("batcher formation cost");
    report.header();
    for size in [64usize, 256, 1024] {
        let mut batcher = Batcher::new(BatchPolicy {
            max_size: size,
            max_delay: std::time::Duration::from_millis(10),
        });
        let mut i = 0usize;
        let stats = b.run(&format!("batcher max_size={size}"), 1.0, || {
            let out: Option<Batch> = batcher.push(trace.packets[i & 8191].clone());
            i += 1;
            keep(out);
        });
        report.add(stats);
    }
}
