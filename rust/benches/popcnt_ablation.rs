//! E5/E7 bench: POPCNT implementation ablation.
//!
//! * E7 — naive unrolled loop vs the HAKMEM tree: element counts
//!   ("a naive implementation ... may require a potentially big number
//!   of elements") plus measured simulator cost of both programs.
//! * E5 — §3 native-POPCNT chip: Table 1's 12-25 collapses to 5-10 and
//!   parallel capacity doubles.
//!
//! `cargo bench --bench popcnt_ablation`

use n2net::baseline::naive_popcount_program;
use n2net::bnn::{BnnModel, PackedBits};
use n2net::compiler::popcount::{naive_elements, tree_elements};
use n2net::compiler::{elements_for_layer, Compiler, CompilerOptions, InputEncoding};
use n2net::rmt::{ChipConfig, ContainerId, PacketParser, Pipeline};
use n2net::util::bench::{default_bencher, Report};
use n2net::util::rng::Rng;

fn main() {
    println!("# E5/E7 — POPCNT ablation");
    println!(
        "{:>10} {:>10} {:>10} {:>16} {:>18}",
        "act bits", "naive el.", "tree el.", "layer el. (tree)", "layer el. (native)"
    );
    for n in [16usize, 32, 64, 128, 256, 512, 1024, 2048] {
        println!(
            "{:>10} {:>10} {:>10} {:>16} {:>18}",
            n,
            naive_elements(n),
            tree_elements(n),
            elements_for_layer(n, &ChipConfig::rmt()),
            elements_for_layer(n, &ChipConfig::rmt_with_popcnt()),
        );
    }
    // §3 claims.
    assert_eq!(elements_for_layer(16, &ChipConfig::rmt_with_popcnt()), 5);
    assert_eq!(elements_for_layer(2048, &ChipConfig::rmt_with_popcnt()), 10);
    println!("§3 range 5-10 reproduced ✓");
    println!(
        "naive@2048 needs {} recirculation passes (vs 1 for the tree layer)\n",
        naive_popcount_program(2048).0.passes(&ChipConfig::rmt())
    );

    let b = default_bencher();
    let mut report = Report::new("measured simulator cost per packet");
    report.header();

    // Naive popcount programs (pure popcount of one vector).
    for n in [32usize, 256, 2048] {
        let (prog, _acc) = naive_popcount_program(n);
        let chip = ChipConfig::rmt();
        let mut pipe = Pipeline::new(chip.clone(), prog, PacketParser::default(), true).unwrap();
        let mut rng = Rng::seed_from_u64(1);
        let v = PackedBits::random(n, &mut rng);
        let mut phv = pipe.fresh_phv();
        let cfg = pipe.chip().phv.clone();
        let stats = b.run(&format!("naive popcount N={n}"), 1.0, || {
            for (k, &wd) in v.words().iter().enumerate() {
                phv.write(ContainerId(k as u16), wd, &cfg);
            }
            pipe.process_phv(&mut phv);
        });
        report.add(stats);
    }

    // Full BNN layer (tree) on stock vs native chip.
    for (name, chip) in [
        ("tree/stock", ChipConfig::rmt()),
        ("native §3", ChipConfig::rmt_with_popcnt()),
    ] {
        for n in [32usize, 256, 2048] {
            let p = n2net::compiler::layout::max_parallel_neurons(&chip, n).min(2048 / n);
            let model = BnnModel::random(n, &[p.max(1)], 5);
            let opts = CompilerOptions {
                input: InputEncoding::PayloadLe { offset: 0 },
                ..Default::default()
            };
            let compiled = Compiler::new(chip.clone(), opts).compile(&model).unwrap();
            let mut pipe = Pipeline::new(
                chip.clone(),
                compiled.program.clone(),
                compiled.parser.clone(),
                true,
            )
            .unwrap();
            let mut rng = Rng::seed_from_u64(2);
            let x = PackedBits::random(n, &mut rng);
            let mut pkt = Vec::new();
            for w in x.words() {
                pkt.extend_from_slice(&w.to_le_bytes());
            }
            let stats = b.run(&format!("layer {name} N={n}"), 1.0, || {
                let _ = pipe.process_packet(&pkt).unwrap();
            });
            report.add(stats);
        }
    }
}
