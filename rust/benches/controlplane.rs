//! Control-plane bench (DESIGN.md §13): (1) the steady-state overhead
//! of signal collection — serving the SAME windowed workload with and
//! without the controller ticking every window — and (2) the closed
//! loop's reaction latency over a uniform → ddos-burst sequence.
//!
//! The acceptance bar (ISSUE 4): collection is pull-based (per-batch
//! counters the tier maintains anyway + a few atomic loads per window),
//! so the adaptive case must track the baseline — the printed overhead
//! figure is the evidence that zero per-packet work was added.
//!
//! Emits machine-readable records to `BENCH_controlplane.json`.
//!
//! `cargo bench --bench controlplane`

use std::sync::Arc;
use std::time::Duration;

use n2net::bnn::BnnModel;
use n2net::controlplane::{
    prefix_classifier, sim_ddos, spawn_live, Controller, LiveConfig, ModelBank,
    Policy, Sim, SimConfig, SystemClock,
};
use n2net::deploy::{Deployment, FieldExtractor, SwapHandle};
use n2net::net::{Scenario, ScenarioSequence};
use n2net::util::bench::{
    default_bencher, keep, write_bench_json, BenchRecord, Report,
};

const BENCH_JSON: &str = "BENCH_controlplane.json";
const N_PACKETS: usize = 16384;
const WINDOW: usize = 1024;
const SHARDS: usize = 2;
const BATCH_SIZE: usize = 256;

fn deployment_for(model: &BnnModel) -> Arc<Deployment> {
    Arc::new(
        Deployment::builder()
            .extractor(FieldExtractor::SrcIp)
            .model("live", model.clone())
            .build()
            .unwrap(),
    )
}

fn main() {
    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut report = Report::new("control plane — collection overhead + reaction");
    report.header();

    // ---- steady-state overhead of signal collection -----------------
    // Same model, same trace, same windowing; the only difference is
    // whether a controller pulls a snapshot and runs detectors/policy
    // at every window boundary. Uniform traffic + an alert-only policy
    // keep the model fixed, so both cases execute identical serving
    // work.
    let model = BnnModel::random(32, &[64, 32], 3);
    let trace = Scenario::Uniform.generate(7, N_PACKETS);
    let deployment = deployment_for(&model);

    let engine = deployment.sharded_engine("live", SHARDS).unwrap();
    let baseline = b.run(
        &format!("steady-serve shards={SHARDS} windows no-controller"),
        N_PACKETS as f64,
        || {
            for chunk in trace.packets.chunks(WINDOW) {
                let r = engine.process_trace(chunk).unwrap();
                keep(r.outputs.len());
            }
        },
    );
    let base_pps = baseline.items_per_sec();
    records.push(BenchRecord::from_stats("controlplane", "batched", BATCH_SIZE, &baseline));
    report.add(baseline);

    let engine = deployment.sharded_engine("live", SHARDS).unwrap();
    // Same serving loop as the baseline closure, plus one controller
    // tick (snapshot pull + detectors + policy) per window. Uniform
    // traffic with an alert-only policy never swaps, so the served
    // program is identical in both cases.
    let mut controller = Controller::new(
        SwapHandle::new(&deployment, "live").unwrap(),
        ModelBank::new("day", model.clone()),
        Policy::parse("on overload do alert cooldown=8").unwrap(),
    )
    .unwrap();
    let adaptive = b.run(
        &format!("steady-serve shards={SHARDS} windows adaptive"),
        N_PACKETS as f64,
        || {
            for chunk in trace.packets.chunks(WINDOW) {
                let r = engine.process_trace(chunk).unwrap();
                keep(r.outputs.len());
                let tick = controller.tick(engine.snapshot());
                keep(tick.events.len());
            }
        },
    );
    let adaptive_pps = adaptive.items_per_sec();
    records.push(BenchRecord::from_stats("controlplane", "batched", BATCH_SIZE, &adaptive));
    report.add(adaptive);

    let overhead = if adaptive_pps > 0.0 && base_pps > 0.0 {
        (base_pps / adaptive_pps - 1.0) * 100.0
    } else {
        0.0
    };
    println!(
        "\nsignal-collection overhead: {overhead:+.1}% \
         (target ~0 — collection is per-batch counters + per-window pulls, \
         nothing per packet)"
    );

    // ---- live-loop overhead: controller thread attached vs detached --
    // The SAME streaming ingest loop (LiveStream push + finish), once
    // with nothing else running and once with a live controller thread
    // pulling snapshots every 2ms on its own clock. Collection stays
    // pull-based, so attached must track detached within noise — this
    // is the ISSUE 5 acceptance figure for the streaming path.
    let engine = Arc::new(deployment.sharded_engine("live", SHARDS).unwrap());
    let detached = b.run(
        &format!("live-stream shards={SHARDS} detached"),
        N_PACKETS as f64,
        || {
            let mut stream = engine.live_stream().unwrap();
            for pkt in &trace.packets {
                stream.push(pkt.clone()).unwrap();
            }
            keep(stream.finish().unwrap().outputs.len());
        },
    );
    let detached_pps = detached.items_per_sec();
    records.push(BenchRecord::from_stats("controlplane", "batched", BATCH_SIZE, &detached));
    report.add(detached);

    let engine = Arc::new(deployment.sharded_engine("live", SHARDS).unwrap());
    let controller = Controller::new(
        SwapHandle::new(&deployment, "live").unwrap(),
        ModelBank::new("day", model.clone()),
        Policy::parse("on overload do alert cooldown=8").unwrap(),
    )
    .unwrap()
    .with_tier(Arc::clone(&engine))
    .unwrap();
    let live = spawn_live(
        Arc::clone(&engine),
        controller,
        Box::new(SystemClock::new(Duration::from_millis(2))),
        LiveConfig::default(),
    );
    let attached = b.run(
        &format!("live-stream shards={SHARDS} attached"),
        N_PACKETS as f64,
        || {
            let mut stream = engine.live_stream().unwrap();
            for pkt in &trace.packets {
                stream.push(pkt.clone()).unwrap();
            }
            keep(stream.finish().unwrap().outputs.len());
        },
    );
    let attached_pps = attached.items_per_sec();
    records.push(BenchRecord::from_stats("controlplane", "batched", BATCH_SIZE, &attached));
    report.add(attached);
    let ticks = live.ticks();
    let controller = live.stop();
    let live_overhead = if attached_pps > 0.0 && detached_pps > 0.0 {
        (detached_pps / attached_pps - 1.0) * 100.0
    } else {
        0.0
    };
    println!(
        "\nlive-loop overhead: {live_overhead:+.1}% with {ticks} snapshot \
         ticks and {} action(s) during the attached runs (target ~0 — the \
         controller thread only pulls counters the tier maintains anyway)",
        controller.events().len()
    );

    // ---- closed-loop reaction latency -------------------------------
    // A fresh deployment/controller per iteration (a swap is stateful);
    // the measured time is the whole loop — serve windows, pull
    // signals, detect, decide, recompile + publish the swap.
    let seq = ScenarioSequence::new(vec![
        (Scenario::Uniform, 2048),
        (Scenario::DdosBurst { ddos: sim_ddos(), peak_fraction: 0.9 }, 4096),
    ]);
    let live = prefix_classifier(0xC0A8_0000);
    let attack = prefix_classifier(0xC0A8_FFFF);
    let cfg = SimConfig { n_shards: SHARDS, window_packets: 512, seed: 11 };
    let mut last_reaction = None;
    let reaction = b.run("closed-loop uniform->ddos-burst (full loop)", 1.0, || {
        let dep = deployment_for(&live);
        let bank = ModelBank::new("day", live.clone()).with_model("attack", attack.clone());
        let policy = Policy::parse("on ddos-ramp do swap attack cooldown=4").unwrap();
        let mut sim = Sim::new(&dep, "live", bank, policy, cfg).unwrap();
        let r = sim.run_sequence(&seq).unwrap();
        last_reaction = r.reaction_windows;
        keep(r.outputs.len());
    });
    records.push(BenchRecord::from_stats("controlplane", "batched", BATCH_SIZE, &reaction));
    report.add(reaction);
    match last_reaction {
        Some(w) => println!(
            "reaction: swap published {w} window(s) of {} packets after attack onset",
            cfg.window_packets
        ),
        None => println!("reaction: WARNING — no swap attributed to the attack"),
    }

    match write_bench_json(BENCH_JSON, "controlplane", &records) {
        Ok(()) => println!("wrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
