//! Timing bench: modeled ASIC latency/throughput for the synthetic
//! 32 -> [64, 32] model (the `n2net::timing` cycle model, DESIGN.md
//! §16) alongside the *measured* host simulator packet rate for the
//! same compiled program on each inference backend. The ratio is the
//! headline of the modeled-vs-host comparison: how far the software
//! simulator sits from the line-rate ASIC it models.
//!
//! Appends machine-readable records to `BENCH_timing.json`.
//!
//! `cargo bench --bench timing`

use n2net::analysis::throughput::{render_modeled_vs_host, ModeledVsHost};
use n2net::backend::BackendKind;
use n2net::bnn::{BnnModel, PackedBits};
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::rmt::ChipConfig;
use n2net::timing::{analyze_compiled, ChipTiming};
use n2net::util::bench::{
    default_bencher, write_bench_json, BenchRecord, Report,
};
use n2net::util::rng::Rng;

const BENCH_JSON: &str = "BENCH_timing.json";
const BATCH: usize = 256;

fn main() {
    let chip = ChipConfig::rmt();
    let model = BnnModel::random(32, &[64, 32], 11);
    let deployment = Deployment::builder()
        .chip(chip.clone())
        .extractor(FieldExtractor::PayloadAt { offset: 0 })
        .model("timing", model)
        .build()
        .unwrap();

    // Modeled side: cycle-accurate pipeline timing for the program the
    // deployment actually compiled.
    let compiled = deployment.compiled("timing").unwrap();
    let timing = ChipTiming::for_chip(&compiled.chip);
    let report = analyze_compiled(&compiled, &timing).unwrap();
    println!("# timing — modeled ASIC vs measured host");
    print!("{}", report.render());

    // Measured side: host packet rate per backend over the same
    // deployment, on a pre-built packet ring (construction unmeasured).
    let mut rng = Rng::seed_from_u64(4);
    let packets: Vec<Vec<u8>> = (0..BATCH)
        .map(|_| {
            let x = PackedBits::random(32, &mut rng);
            let mut pkt = Vec::new();
            for w in x.words() {
                pkt.extend_from_slice(&w.to_le_bytes());
            }
            pkt
        })
        .collect();
    let refs: Vec<&[u8]> = packets.iter().map(|p| p.as_slice()).collect();

    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rows: Vec<ModeledVsHost> = Vec::new();
    let mut bench_report = Report::new("host packet rate (measured, per backend)");
    bench_report.header();
    for kind in [
        BackendKind::Scalar,
        BackendKind::Batched,
        BackendKind::Reference,
        BackendKind::Specialized,
    ] {
        let name = kind.name();
        let mut session = deployment.session_with("timing", kind).unwrap();
        let mut out = Vec::new();
        let stats = b.run(&format!("{name} (B={BATCH})"), BATCH as f64, || {
            session.classify_batch(&refs, &mut out).unwrap();
            std::hint::black_box(out.len());
        });
        rows.push(ModeledVsHost {
            case: name.to_string(),
            host_pps: stats.items_per_sec(),
            modeled_pps: report.modeled_pps,
        });
        records.push(BenchRecord::from_stats("timing", name, BATCH, &stats));
        bench_report.add(stats);
    }

    println!();
    print!("{}", render_modeled_vs_host(&rows));

    match write_bench_json(BENCH_JSON, "timing", &records) {
        Ok(()) => println!("\nwrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
