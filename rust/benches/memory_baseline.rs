//! E8 bench: BNN vs exact-match LUT — accuracy per SRAM bit on the DDoS
//! workload, plus lookup/classify cost in the simulator.
//!
//! Uses the trained artifact model when available (`make artifacts`),
//! else a random one (accuracy column then only shows the LUT trend).
//!
//! `cargo bench --bench memory_baseline`

use n2net::baseline::LutClassifier;
use n2net::bnn::io::{DdosDoc, SubnetDoc};
use n2net::bnn::{self, BnnModel};
use n2net::compiler::{Compiler, CompilerOptions, InputEncoding};
use n2net::net::packet::IPV4_SRC_OFFSET;
use n2net::net::{TraceGenerator, TraceKind};
use n2net::rmt::{ChipConfig, Pipeline};
use n2net::runtime::Oracle;
use n2net::util::bench::{default_bencher, keep, Report};
use n2net::util::rng::Rng;

fn fallback_ddos() -> DdosDoc {
    DdosDoc {
        subnets: vec![
            SubnetDoc { prefix: 0xC0A80000, prefix_len: 16 },
            SubnetDoc { prefix: 0x0A400000, prefix_len: 12 },
        ],
        attack_fraction: 0.5,
        seed: 1,
    }
}

fn main() {
    let dir = Oracle::default_dir();
    let (model, ddos, trained) = match bnn::load_weights(dir.join("weights.json")) {
        Ok((m, doc)) => (m, doc.ddos, true),
        Err(_) => (BnnModel::random(32, &[64, 32, 1], 9), fallback_ddos(), false),
    };
    println!(
        "# E8 — accuracy per SRAM bit ({} model)",
        if trained { "trained" } else { "random" }
    );

    let mut gen = TraceGenerator::new(42);
    let trace = gen.generate(&TraceKind::Ddos { ddos: ddos.clone() }, 4000);

    // BNN accuracy via the reference forward (same bits as the switch).
    let bnn_acc = trace
        .keys
        .iter()
        .zip(&trace.labels)
        .filter(|(&k, &l)| {
            bnn::forward(&model, &bnn::PackedBits::from_u32(k)).get(0) as u32 == l
        })
        .count() as f64
        / trace.keys.len() as f64;
    let bnn_bits = model.spec.weight_bits_total();
    println!("\n{:>14} {:>12} {:>10}", "SRAM bits", "classifier", "accuracy");
    println!("{:>14} {:>12} {:>9.2}%", bnn_bits, "BNN", bnn_acc * 100.0);

    let mut rng = Rng::seed_from_u64(7);
    for budget in [bnn_bits, 16 * bnn_bits, 256 * bnn_bits, 11_562_500] {
        let mut lut = LutClassifier::with_budget_bits(budget);
        lut.populate_from(&ddos, &mut rng);
        let acc = lut.accuracy(&trace.keys, &trace.labels);
        println!(
            "{:>14} {:>12} {:>9.2}%",
            budget,
            format!("LUT({})", lut.n_entries()),
            acc * 100.0
        );
    }

    // Measured per-packet cost: BNN pipeline vs LUT match stage on the
    // same simulator.
    let b = default_bencher();
    let mut report = Report::new("per-packet classification cost (simulator)");
    report.header();

    let opts = CompilerOptions {
        input: InputEncoding::BigEndianField { offset: IPV4_SRC_OFFSET },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts).compile(&model).unwrap();
    let mut pipe = Pipeline::new(
        ChipConfig::rmt(),
        compiled.program.clone(),
        compiled.parser.clone(),
        true,
    )
    .unwrap();
    let frame = &trace.packets[0];
    let s = b.run("BNN pipeline classify", 1.0, || {
        keep(pipe.process_packet(frame).unwrap());
    });
    report.add(s);

    let mut lut = LutClassifier::with_budget_bits(1_048_576);
    lut.populate_from(&ddos, &mut rng);
    let keys = trace.keys.clone();
    let mut i = 0usize;
    let s = b.run("LUT exact-match classify", 1.0, || {
        let k = keys[i % keys.len()];
        i += 1;
        keep(lut.classify(k));
    });
    report.add(s);

    println!(
        "\n(the ASIC model makes both free at line rate — the point of E8 is\n\
         the accuracy column: structure generalizes, enumeration does not)"
    );
}
