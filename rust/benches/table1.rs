//! E1 bench: regenerate Table 1 (both chip variants) and measure the
//! compiler itself (model → pipeline program) across activation widths.
//!
//! `cargo bench --bench table1`

use n2net::bnn::BnnModel;
use n2net::compiler::layout::max_parallel_neurons;
use n2net::compiler::{render_table1, table1, Compiler, CompilerOptions, InputEncoding};
use n2net::rmt::ChipConfig;
use n2net::util::bench::{default_bencher, keep, Report};

fn main() {
    println!("# E1 — Table 1 regeneration");
    println!("\n## stock RMT chip (paper values)");
    print!("{}", render_table1(&ChipConfig::rmt()));
    println!("\n## + native POPCNT (§3: 5-10 range, 2x parallelism)");
    print!("{}", render_table1(&ChipConfig::rmt_with_popcnt()));

    // Assert the paper's numbers inside the bench too — a bench that
    // silently regenerates the wrong table is worse than none.
    let paper = [
        (16, 128, 12),
        (32, 64, 14),
        (64, 32, 16),
        (128, 16, 18),
        (256, 8, 20),
        (512, 4, 22),
        (1024, 2, 24),
        (2048, 1, 25),
    ];
    for (row, (n, p, e)) in table1(&ChipConfig::rmt()).iter().zip(paper) {
        assert_eq!(
            (row.activation_bits, row.parallel_neurons, row.elements),
            (n, p, e)
        );
    }
    println!("table matches the paper exactly ✓");

    // Compiler latency per width (single maximal group, like Table 1;
    // 16b capped at 64 parallel on the uniform-32b PHV, see DESIGN.md).
    let b = default_bencher();
    let mut report = Report::new("compiler latency (model -> pipeline program)");
    report.header();
    let chip = ChipConfig::rmt();
    for n in [16usize, 32, 64, 128, 256, 512, 1024, 2048] {
        let p = if n == 16 { 64 } else { max_parallel_neurons(&chip, n) };
        let model = BnnModel::random(n, &[p], 7);
        let opts = CompilerOptions {
            input: InputEncoding::PayloadLe { offset: 0 },
            ..Default::default()
        };
        let compiler = Compiler::new(chip.clone(), opts);
        let stats = b.run(&format!("compile N={n} M={p}"), 1.0, || {
            keep(compiler.compile(&model).unwrap());
        });
        report.add(stats);
    }
}
