//! E3 bench: the paper's throughput-scaling series — modeled ASIC rate
//! (960 Mpps × parallel neurons) alongside the *measured* software
//! simulator rate for the same programs, on both the scalar per-packet
//! path and the batched SoA path (DESIGN.md §10).
//!
//! Appends machine-readable records to `BENCH_pipeline.json`.
//!
//! `cargo bench --bench throughput`

use n2net::analysis::throughput::throughput_table;
use n2net::bnn::{BnnModel, PackedBits};
use n2net::compiler::layout::max_parallel_neurons;
use n2net::compiler::{Compiler, CompilerOptions, InputEncoding};
use n2net::rmt::{BatchedTape, ChipConfig, Pipeline};
use n2net::util::bench::{
    default_bencher, format_rate, write_bench_json, BenchRecord, Report,
};
use n2net::util::rng::Rng;

const BENCH_JSON: &str = "BENCH_pipeline.json";
const BATCH: usize = 256;

fn main() {
    let chip = ChipConfig::rmt();
    println!("# E3 — throughput scaling");
    println!(
        "{:>10} {:>10} {:>9} {:>14} {:>16}",
        "act bits", "parallel", "elements", "ASIC Mpps", "ASIC neurons/s"
    );
    for r in throughput_table(&chip) {
        println!(
            "{:>10} {:>10} {:>9} {:>14.0} {:>16}",
            r.activation_bits,
            r.parallel_neurons,
            r.elements,
            r.pps / 1e6,
            format_rate(r.neurons_per_sec)
        );
    }
    // Paper headline: 960 M neurons/s at 2048 b activations.
    let r2048 = throughput_table(&chip)
        .into_iter()
        .find(|r| r.activation_bits == 2048)
        .unwrap();
    assert_eq!(r2048.neurons_per_sec, 960e6);
    println!("paper headline reproduced: 960 M neurons/s @ 2048 b ✓");

    // Measured software-simulator packet rate per configuration, scalar
    // vs batched SoA.
    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut report =
        Report::new("software simulator packet rate (measured, per config)");
    report.header();
    for n in [16usize, 32, 64, 256, 1024, 2048] {
        let p = if n == 16 { 64 } else { max_parallel_neurons(&chip, n) };
        let model = BnnModel::random(n, &[p], 11);
        let opts = CompilerOptions {
            input: InputEncoding::PayloadLe { offset: 0 },
            ..Default::default()
        };
        let compiled = Compiler::new(chip.clone(), opts).compile(&model).unwrap();
        let mut pipe = Pipeline::new(
            chip.clone(),
            compiled.program.clone(),
            compiled.parser.clone(),
            true,
        )
        .unwrap();
        // Pre-build a packet ring so packet construction isn't measured.
        let mut rng = Rng::seed_from_u64(4);
        let packets: Vec<Vec<u8>> = (0..BATCH)
            .map(|_| {
                let x = PackedBits::random(n, &mut rng);
                let mut pkt = Vec::new();
                for w in x.words() {
                    pkt.extend_from_slice(&w.to_le_bytes());
                }
                pkt
            })
            .collect();
        let mut i = 0usize;
        let stats = b.run(&format!("scalar N={n} M={p} (pkt/iter)"), 1.0, || {
            let pkt = &packets[i % BATCH];
            i += 1;
            let _ = pipe.process_packet(pkt).unwrap();
        });
        records.push(BenchRecord::from_stats("throughput", "scalar", 1, &stats));
        report.add(stats);

        let mut tape = BatchedTape::new(
            chip.clone(),
            compiled.program.clone(),
            compiled.parser.clone(),
            true,
        )
        .unwrap();
        let stats = b.run(
            &format!("batched N={n} M={p} (B={BATCH})"),
            BATCH as f64,
            || {
                let out = tape.process_batch(&packets);
                std::hint::black_box(out.n_ok());
            },
        );
        records.push(BenchRecord::from_stats("throughput", "batched", BATCH, &stats));
        report.add(stats);
    }

    match write_bench_json(BENCH_JSON, "throughput", &records) {
        Ok(()) => println!("\nwrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
