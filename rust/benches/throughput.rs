//! E3 bench: the paper's throughput-scaling series — modeled ASIC rate
//! (960 Mpps × parallel neurons) alongside the *measured* software
//! simulator rate for the same programs, on both the scalar per-packet
//! path and the batched SoA path (DESIGN.md §10), each served through a
//! [`n2net::deploy::Deployment`] session (the canonical serving path).
//!
//! Appends machine-readable records to `BENCH_pipeline.json`.
//!
//! `cargo bench --bench throughput`

use n2net::analysis::throughput::throughput_table;
use n2net::backend::BackendKind;
use n2net::bnn::{BnnModel, PackedBits};
use n2net::compiler::layout::max_parallel_neurons;
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::rmt::ChipConfig;
use n2net::util::bench::{
    default_bencher, format_rate, write_bench_json, BenchRecord, Report,
};
use n2net::util::rng::Rng;

const BENCH_JSON: &str = "BENCH_pipeline.json";
const BATCH: usize = 256;

fn main() {
    let chip = ChipConfig::rmt();
    println!("# E3 — throughput scaling");
    println!(
        "{:>10} {:>10} {:>9} {:>14} {:>16}",
        "act bits", "parallel", "elements", "ASIC Mpps", "ASIC neurons/s"
    );
    for r in throughput_table(&chip).unwrap() {
        println!(
            "{:>10} {:>10} {:>9} {:>14.0} {:>16}",
            r.activation_bits,
            r.parallel_neurons,
            r.elements,
            r.pps / 1e6,
            format_rate(r.neurons_per_sec)
        );
    }
    // Paper headline: 960 M neurons/s at 2048 b activations.
    let r2048 = throughput_table(&chip)
        .unwrap()
        .into_iter()
        .find(|r| r.activation_bits == 2048)
        .unwrap();
    assert_eq!(r2048.neurons_per_sec, 960e6);
    println!("paper headline reproduced: 960 M neurons/s @ 2048 b ✓");

    // Measured software-simulator packet rate per configuration, scalar
    // vs batched SoA, through deployment sessions.
    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut report =
        Report::new("software simulator packet rate (measured, per config)");
    report.header();
    for n in [16usize, 32, 64, 256, 1024, 2048] {
        let p = if n == 16 { 64 } else { max_parallel_neurons(&chip, n) };
        let model = BnnModel::random(n, &[p], 11);
        let deployment = Deployment::builder()
            .chip(chip.clone())
            .extractor(FieldExtractor::PayloadAt { offset: 0 })
            .model("bench", model)
            .build()
            .unwrap();
        // Pre-build a packet ring so packet construction isn't measured.
        let mut rng = Rng::seed_from_u64(4);
        let packets: Vec<Vec<u8>> = (0..BATCH)
            .map(|_| {
                let x = PackedBits::random(n, &mut rng);
                let mut pkt = Vec::new();
                for w in x.words() {
                    pkt.extend_from_slice(&w.to_le_bytes());
                }
                pkt
            })
            .collect();
        let refs: Vec<&[u8]> = packets.iter().map(|p| p.as_slice()).collect();

        let mut scalar = deployment
            .session_with("bench", BackendKind::Scalar)
            .unwrap();
        let mut i = 0usize;
        let mut out = Vec::new();
        // Fixed-size slot: no per-iteration allocation in the measured loop.
        let mut one: [&[u8]; 1] = [refs[0]];
        let stats = b.run(&format!("scalar N={n} M={p} (pkt/iter)"), 1.0, || {
            one[0] = refs[i % BATCH];
            i += 1;
            scalar.classify_batch(&one, &mut out).unwrap();
        });
        records.push(BenchRecord::from_stats("throughput", "scalar", 1, &stats));
        report.add(stats);

        let mut batched = deployment
            .session_with("bench", BackendKind::Batched)
            .unwrap();
        let mut out = Vec::new();
        let stats = b.run(
            &format!("batched N={n} M={p} (B={BATCH})"),
            BATCH as f64,
            || {
                batched.classify_batch(&refs, &mut out).unwrap();
                std::hint::black_box(out.len());
            },
        );
        records.push(BenchRecord::from_stats("throughput", "batched", BATCH, &stats));
        report.add(stats);
    }

    match write_bench_json(BENCH_JSON, "throughput", &records) {
        Ok(()) => println!("\nwrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
