//! Verifier bench: what the publish gate costs (ISSUE 8 satellite).
//! `ModelArtifact::new` runs the full static verification of
//! DESIGN.md §17 on every hot-swap, off the hot path but on the swap
//! path — so its cost bounds how fast the control plane can republish.
//! Measured here: `verify_compiled` (all three analysis layers +
//! translation-validated optimizer run) and `run_pipeline_validated`
//! alone, against the plain `run_pipeline` baseline.
//!
//! Appends machine-readable records to `BENCH_verify.json`.
//!
//! `cargo bench --bench verify`

use n2net::bnn::BnnModel;
use n2net::compiler::ir::IrProgram;
use n2net::compiler::verify::verify_compiled;
use n2net::compiler::{passes, Compiler, CompilerOptions, InputEncoding};
use n2net::rmt::ChipConfig;
use n2net::util::bench::{
    default_bencher, write_bench_json, BenchRecord, Report,
};

const BENCH_JSON: &str = "BENCH_verify.json";

fn main() {
    let model = BnnModel::random(32, &[64, 32], 11);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        ..Default::default()
    };
    let compiled =
        Compiler::new(ChipConfig::rmt(), opts).compile(&model).unwrap();
    let ir = IrProgram::lower(
        &compiled.program,
        &compiled.chip.phv,
        &compiled.layout.output,
    )
    .unwrap();

    println!("# verify — publish-gate cost (32 -> [64, 32])");
    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut report = Report::new("static verification (per artifact)");
    report.header();

    let stats = b.run("verify_compiled", 1.0, || {
        std::hint::black_box(verify_compiled(&compiled).is_clean());
    });
    records.push(BenchRecord::from_stats("verify", "verify_compiled", 1, &stats));
    report.add(stats);

    let stats = b.run("pipeline (validated)", 1.0, || {
        let mut opt = ir.clone();
        passes::run_pipeline_validated(&mut opt, &passes::host_pipeline())
            .unwrap();
        std::hint::black_box(opt.n_instrs());
    });
    records.push(BenchRecord::from_stats("verify", "pipeline_validated", 1, &stats));
    report.add(stats);

    let stats = b.run("pipeline (baseline)", 1.0, || {
        let mut opt = ir.clone();
        passes::run_pipeline(&mut opt, &passes::host_pipeline());
        std::hint::black_box(opt.n_instrs());
    });
    records.push(BenchRecord::from_stats("verify", "pipeline_baseline", 1, &stats));
    report.add(stats);

    match write_bench_json(BENCH_JSON, "verify", &records) {
        Ok(()) => println!("\nwrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
