//! Sharded-serving bench (DESIGN.md §12) — aggregate throughput of the
//! flow-affinity shard tier across shard counts and traffic scenarios,
//! on the batched backend.
//!
//! The acceptance bar (ISSUE 3): `shards=4` must show ≥2× the
//! `shards=1` aggregate rate on the batched backend, including under
//! `zipf-heavy-hitter` skew (where flow affinity concentrates the
//! hitter on one shard — the measured imbalance is printed so the cost
//! of affinity stays visible).
//!
//! Emits machine-readable records to `BENCH_shard.json` (`case` carries
//! the scenario and shard count) alongside the speedup summary.
//!
//! `cargo bench --bench shard`

use n2net::bnn::BnnModel;
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::net::Scenario;
use n2net::util::bench::{
    default_bencher, keep, write_bench_json, BenchRecord, Report,
};

const BENCH_JSON: &str = "BENCH_shard.json";
/// Large enough that per-iteration setup (spawning the shard workers,
/// building one backend per shard — a cost that grows with the shard
/// count) is amortized to noise against the classify work, so the
/// shards=4 vs shards=1 ratio measures steady-state throughput.
const N_PACKETS: usize = 16384;
const SHARD_COUNTS: &[usize] = &[1, 2, 4];
/// Per-shard batch bound (the deployment default); the shard count
/// rides in each record's `case` string (`"<scenario> shards=N"`).
const BATCH_SIZE: usize = 256;

fn main() {
    // The paper's use-case model behind the canonical deployment path.
    let model = BnnModel::random(32, &[64, 32], 3);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .model("shard-bench", model.clone())
        .build()
        .unwrap();

    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut report = Report::new("sharded serving — aggregate packet rate");
    report.header();
    let mut summary: Vec<String> = Vec::new();

    for name in ["uniform", "zipf-heavy-hitter", "ddos-burst", "malformed-fuzz"] {
        let scenario = Scenario::parse(name).unwrap();
        let trace = scenario.generate(7, N_PACKETS);
        let mut base_pps = 0.0f64;
        for &shards in SHARD_COUNTS {
            let engine = deployment.sharded_engine("shard-bench", shards).unwrap();
            let stats = b.run(
                &format!("{name} shards={shards}"),
                N_PACKETS as f64,
                || {
                    let r = engine.process_trace(&trace.packets).unwrap();
                    keep(r.outputs.len());
                },
            );
            let pps = stats.items_per_sec();
            if shards == 1 {
                base_pps = pps;
            } else if base_pps > 0.0 {
                // One representative run for the shard-load shape.
                let imbalance =
                    engine.process_trace(&trace.packets).unwrap().imbalance();
                summary.push(format!(
                    "{name}: shards={shards} -> {:.2}x over shards=1 \
                     (imbalance {imbalance:.2})",
                    pps / base_pps
                ));
            }
            records.push(BenchRecord::from_stats(
                "shard",
                "batched",
                BATCH_SIZE,
                &stats,
            ));
            report.add(stats);
        }
    }

    // The keyed multi-tenant registry under mixed-id traffic.
    let keyed = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .keyed(n2net::net::MODEL_ID_OFFSET)
        .model_with_id("tenant-a", 1, model.clone())
        .model_with_id("tenant-b", 2, BnnModel::random(32, &[64, 32], 4))
        .build()
        .unwrap();
    let mix = Scenario::parse("multi-tenant-mix")
        .unwrap()
        .with_model_ids(vec![1, 2])
        .generate(9, N_PACKETS);
    let mut base_pps = 0.0f64;
    for &shards in SHARD_COUNTS {
        let engine = keyed.sharded_engine_keyed(shards).unwrap();
        let stats = b.run(
            &format!("multi-tenant-mix shards={shards}"),
            N_PACKETS as f64,
            || {
                let r = engine.process_trace(&mix.packets).unwrap();
                keep(r.outputs.len());
            },
        );
        let pps = stats.items_per_sec();
        if shards == 1 {
            base_pps = pps;
        } else if base_pps > 0.0 {
            summary.push(format!(
                "multi-tenant-mix: shards={shards} -> {:.2}x over shards=1",
                pps / base_pps
            ));
        }
        records.push(BenchRecord::from_stats(
            "shard",
            "batched",
            BATCH_SIZE,
            &stats,
        ));
        report.add(stats);
    }

    println!("\nscaling (aggregate pps, same scenario):");
    for line in &summary {
        println!("  {line}");
    }
    println!(
        "target (DESIGN.md §12): shards=4 ≥ 2x shards=1 on the batched backend"
    );

    match write_bench_json(BENCH_JSON, "shard", &records) {
        Ok(()) => println!("wrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
