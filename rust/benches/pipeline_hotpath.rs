//! L3 hot-path microbenchmarks — the §Perf instrument (DESIGN.md §9).
//!
//! Measures the simulator's inner loops in isolation:
//!   * element execution (per element, per op)
//!   * full per-packet pipeline traversal (the use-case model)
//!   * parsing
//!   * PHV allocation vs reuse
//!
//! `cargo bench --bench pipeline_hotpath`

use n2net::bnn::BnnModel;
use n2net::compiler::{Compiler, CompilerOptions, InputEncoding};
use n2net::net::packet::PacketBuilder;
use n2net::rmt::{ChipConfig, Phv, Pipeline};
use n2net::util::bench::{default_bencher, keep, Report};

fn main() {
    let chip = ChipConfig::rmt();
    // The paper's use-case model: 32b -> 64 -> 32, 30 elements.
    let model = BnnModel::random(32, &[64, 32], 3);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe {
            offset: n2net::net::N2NET_PAYLOAD_OFFSET,
        },
        ..Default::default()
    };
    let compiled = Compiler::new(chip.clone(), opts).compile(&model).unwrap();
    let n_elements = compiled.program.n_elements();
    let total_ops: usize = compiled
        .program
        .elements
        .iter()
        .map(|e| e.slot_cost())
        .sum();
    println!(
        "# L3 hot path — use-case model: {n_elements} elements, {total_ops} op slots"
    );

    let b = default_bencher();
    let mut report = Report::new("simulator inner loops");
    report.header();

    // Full packet: parse + 30 elements.
    let frame = PacketBuilder::default().build_activations(&[0xDEADBEEF]);
    let mut pipe = Pipeline::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        false,
    )
    .unwrap();
    let s = b.run("process_packet (parse+30 elem)", 1.0, || {
        keep(pipe.process_packet(&frame).unwrap());
    });
    let per_elem = s.median_ns / n_elements as f64;
    let per_op = s.median_ns / total_ops as f64;
    report.add(s);

    // PHV-reuse path (no per-packet allocation).
    let mut phv = Phv::zeroed(&chip.phv);
    compiled
        .parser
        .parse(&frame, &mut phv, &chip.phv)
        .unwrap();
    let template = phv.clone();
    let s = b.run("process_phv (30 elem, PHV reused)", 1.0, || {
        phv.clone_from(&template);
        pipe.process_phv(&mut phv);
        keep(phv.read(n2net::rmt::ContainerId(0)));
    });
    report.add(s);

    // Parser alone.
    let mut phv2 = Phv::zeroed(&chip.phv);
    let s = b.run("parser only", 1.0, || {
        compiled.parser.parse(&frame, &mut phv2, &chip.phv).unwrap();
    });
    report.add(s);

    // PHV allocation cost (what process_packet pays per packet).
    let s = b.run("Phv::zeroed alloc", 1.0, || {
        keep(Phv::zeroed(&chip.phv));
    });
    report.add(s);

    println!(
        "\nderived: ~{:.0} ns/element, ~{:.1} ns/op-slot",
        per_elem, per_op
    );
    println!(
        "target (DESIGN.md §9): ≥1 M packets/s single-core for this model \
         (≤1000 ns/packet)"
    );
}
