//! L3 hot-path microbenchmarks — the §Perf instrument (DESIGN.md §9).
//!
//! Measures the simulator's inner loops in isolation:
//!   * full per-packet classification through a `deploy::Session` on
//!     the scalar backend (the use-case model)
//!   * batched SoA classification at increasing batch sizes (§10)
//!   * the specializing codegen backend (§15) head-to-head with the
//!     batched interpreter on the same model and batch sizes
//!   * parsing / PHV allocation (low-level simulator internals, below
//!     the deployment API)
//!
//! Classifiers are constructed through [`n2net::deploy::Deployment`] —
//! the same path apps and the CLI use — so the measured cost includes
//! the session seam (one atomic version peek per batch).
//!
//! Emits machine-readable records to `BENCH_pipeline.json` (pps, batch
//! size, backend) so the perf trajectory is tracked across PRs.
//!
//! `cargo bench --bench pipeline_hotpath`

use n2net::backend::BackendKind;
use n2net::bnn::BnnModel;
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::net::packet::PacketBuilder;
use n2net::rmt::{ChipConfig, Phv, Pipeline};
use n2net::util::bench::{
    default_bencher, keep, write_bench_json, BenchRecord, Report,
};

const BENCH_JSON: &str = "BENCH_pipeline.json";

fn main() {
    let chip = ChipConfig::rmt();
    // The paper's use-case model: 32b -> 64 -> 32, 30 elements.
    let model = BnnModel::random(32, &[64, 32], 3);
    let deployment = Deployment::builder()
        .chip(chip.clone())
        .extractor(FieldExtractor::Payload)
        .model("usecase", model)
        .build()
        .unwrap();
    let compiled = deployment.compiled("usecase").unwrap();
    let n_elements = compiled.program.n_elements();
    let total_ops: usize = compiled
        .program
        .elements
        .iter()
        .map(|e| e.slot_cost())
        .sum();
    println!(
        "# L3 hot path — use-case model: {n_elements} elements, {total_ops} op slots"
    );

    let b = default_bencher();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut report = Report::new("simulator inner loops");
    report.header();

    // Full packet: parse + 30 elements, one packet at a time, through
    // the scalar session.
    let frame = PacketBuilder::default().build_activations(&[0xDEADBEEF]);
    let mut scalar = deployment
        .session_with("usecase", BackendKind::Scalar)
        .unwrap();
    let frame_refs: Vec<&[u8]> = vec![&frame];
    let mut out = Vec::new();
    let scalar_stats = b.run("scalar session (parse+30 elem)", 1.0, || {
        scalar.classify_batch(&frame_refs, &mut out).unwrap();
        keep(out.first().copied());
    });
    let per_elem = scalar_stats.median_ns / n_elements as f64;
    let per_op = scalar_stats.median_ns / total_ops as f64;
    let scalar_pps = scalar_stats.items_per_sec();
    records.push(BenchRecord::from_stats(
        "pipeline_hotpath",
        "scalar",
        1,
        &scalar_stats,
    ));
    report.add(scalar_stats);

    // PHV-reuse path (no per-packet allocation) — a low-level simulator
    // internal below the deployment API.
    let mut pipe = Pipeline::new(
        chip.clone(),
        compiled.program.clone(),
        compiled.parser.clone(),
        false,
    )
    .unwrap();
    let mut phv = Phv::zeroed(&chip.phv);
    compiled
        .parser
        .parse(&frame, &mut phv, &chip.phv)
        .unwrap();
    let template = phv.clone();
    let s = b.run("process_phv (30 elem, PHV reused)", 1.0, || {
        phv.clone_from(&template);
        pipe.process_phv(&mut phv);
        keep(phv.read(n2net::rmt::ContainerId(0)));
    });
    report.add(s);

    // Batched SoA classification across batch sizes (same model, same
    // parse): the op dispatch amortizes over the whole batch.
    let mut batched = deployment
        .session_with("usecase", BackendKind::Batched)
        .unwrap();
    let mut speedup_at_64 = 0.0f64;
    let mut batched_pps: Vec<(usize, f64)> = Vec::new();
    for batch_size in [1usize, 16, 64, 256, 1024] {
        let packets: Vec<Vec<u8>> = (0..batch_size)
            .map(|i| {
                PacketBuilder::default()
                    .build_activations(&[0xDEADBEEF ^ (i as u32).wrapping_mul(0x9E37)])
            })
            .collect();
        let refs: Vec<&[u8]> = packets.iter().map(|p| p.as_slice()).collect();
        let mut out = Vec::new();
        let s = b.run(
            &format!("batched session (B={batch_size})"),
            batch_size as f64,
            || {
                batched.classify_batch(&refs, &mut out).unwrap();
                keep(out.len());
            },
        );
        let pps = s.items_per_sec();
        if batch_size == 64 {
            speedup_at_64 = pps / scalar_pps;
        }
        batched_pps.push((batch_size, pps));
        records.push(BenchRecord::from_stats(
            "pipeline_hotpath",
            "batched",
            batch_size,
            &s,
        ));
        report.add(s);
    }

    // Specialized codegen backend head-to-head: the SAME model and the
    // SAME batch sizes through the deploy-time monomorphized kernels
    // (IR lowered, pass-optimized, compiled to fused closures — no
    // per-op dispatch). The win over `batched` is the tentpole's
    // headline number.
    let mut specialized = deployment
        .session_with("usecase", BackendKind::Specialized)
        .unwrap();
    let mut head_to_head: Vec<(usize, f64)> = Vec::new();
    for (batch_size, bat_pps) in batched_pps {
        let packets: Vec<Vec<u8>> = (0..batch_size)
            .map(|i| {
                PacketBuilder::default()
                    .build_activations(&[0xDEADBEEF ^ (i as u32).wrapping_mul(0x9E37)])
            })
            .collect();
        let refs: Vec<&[u8]> = packets.iter().map(|p| p.as_slice()).collect();
        let mut out = Vec::new();
        let s = b.run(
            &format!("specialized session (B={batch_size})"),
            batch_size as f64,
            || {
                specialized.classify_batch(&refs, &mut out).unwrap();
                keep(out.len());
            },
        );
        head_to_head.push((batch_size, s.items_per_sec() / bat_pps));
        records.push(BenchRecord::from_stats(
            "pipeline_hotpath",
            "specialized",
            batch_size,
            &s,
        ));
        report.add(s);
    }

    // Parser alone.
    let mut phv2 = Phv::zeroed(&chip.phv);
    let s = b.run("parser only", 1.0, || {
        compiled.parser.parse(&frame, &mut phv2, &chip.phv).unwrap();
    });
    report.add(s);

    // PHV allocation cost (what per-packet processing pays).
    let s = b.run("Phv::zeroed alloc", 1.0, || {
        keep(Phv::zeroed(&chip.phv));
    });
    report.add(s);

    println!(
        "\nderived: ~{:.0} ns/element, ~{:.1} ns/op-slot (scalar), \
         batched speedup at B=64: {:.2}x",
        per_elem, per_op, speedup_at_64
    );
    let ratios: Vec<String> = head_to_head
        .iter()
        .map(|(bs, r)| format!("B={bs}: {r:.2}x"))
        .collect();
    println!("specialized vs batched (same model/batches): {}", ratios.join(", "));
    println!(
        "target (DESIGN.md §9/§10): ≥1 M packets/s single-core scalar for \
         this model, ≥2x simulated-pps for the batched path at B≥64"
    );

    match write_bench_json(BENCH_JSON, "pipeline_hotpath", &records) {
        Ok(()) => println!("wrote {} records to {BENCH_JSON}", records.len()),
        Err(e) => eprintln!("warning: could not write {BENCH_JSON}: {e}"),
    }
}
