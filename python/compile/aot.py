"""AOT export: train -> weights.json + model.hlo.txt (+ meta.json).

Interchange format is HLO **text**, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (all consumed by the Rust side):

* ``model.hlo.txt``  — packed BNN forward pass, fixed batch; lowered from
  the same jaxpr the pytest suite validates (Pallas kernel, interpret
  mode). Executed from Rust via PJRT as the golden oracle.
* ``weights.json``   — packed per-layer weights + BnnSpec + the DDoS
  distribution parameters + training metrics. Input to the N2Net
  compiler (rust/src/compiler) and the Rust trace generator.
* ``meta.json``      — artifact shape manifest for the Rust runtime
  (batch, words, output arities), so shape handling is data-driven.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset, model, train
from .kernels import ref

ORACLE_BATCH = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(spec: model.BnnSpec, batch: int) -> str:
    """Lower the packed forward pass with weights as *parameters*.

    Signature of the lowered function:
    (x_packed u32[batch, W0], w_0 u32[M_0, W_0], ..., w_{L-1}) ->
    tuple(final_popcount i32[batch, M_last], sign_packed_0, ..., sign_packed_L-1)
    — per-layer packed sign bits so Rust can cross-check every pipeline
    layer, not just the output.

    Weights MUST be parameters, not closed-over constants: the HLO text
    printer elides large constants (`constant({...})`), which the old
    XLA 0.5.1 text parser then reads back as garbage. Parameters also
    mean one artifact serves any weights of the same architecture — the
    Rust runtime feeds the weights it loaded from weights.json.
    """

    def fwd(x_packed, *wts):
        pop, signs = model.forward_packed(spec, list(wts), x_packed)
        return (pop, *signs)

    x_spec = jax.ShapeDtypeStruct((batch, ref.n_words(spec.in_bits)), jnp.uint32)
    w_specs = [
        jax.ShapeDtypeStruct((m, ref.n_words(n)), jnp.uint32)
        for (m, n) in spec.layer_shapes()
    ]
    lowered = jax.jit(fwd).lower(x_spec, *w_specs)
    text = to_hlo_text(lowered)
    if "constant({...}" in text:
        raise RuntimeError(
            "HLO text contains elided large constants — they would load as "
            "garbage in the Rust runtime; keep weights as parameters"
        )
    return text


def export(out_dir: str, cfg: train.TrainConfig | None = None, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    cfg = cfg or train.TrainConfig()
    if verbose:
        print(f"[aot] training {cfg.spec.layer_sizes} BNN on synthetic DDoS task")
    _params, packed, metrics, ddos = train.train(cfg, verbose=verbose)

    weights_doc = {
        "format": "n2net-weights-v1",
        "spec": {
            "in_bits": cfg.spec.in_bits,
            "layer_sizes": list(cfg.spec.layer_sizes),
        },
        "layers": [
            {
                "neurons": m,
                "in_bits": n,
                "threshold": (n + 1) // 2,
                "weights_packed": [[int(v) for v in row] for row in w],
            }
            for (m, n), w in zip(cfg.spec.layer_shapes(), packed)
        ],
        "ddos": ddos.to_json(),
        "metrics": metrics,
    }
    wpath = os.path.join(out_dir, "weights.json")
    with open(wpath, "w") as f:
        json.dump(weights_doc, f)
    if verbose:
        print(f"[aot] wrote {wpath}")

    hlo = lower_forward(cfg.spec, ORACLE_BATCH)
    hpath = os.path.join(out_dir, "model.hlo.txt")
    with open(hpath, "w") as f:
        f.write(hlo)
    if verbose:
        print(f"[aot] wrote {hpath} ({len(hlo)} chars)")

    # Golden vectors: a few inputs + expected outputs so the Rust runtime
    # test can assert numerics without re-running python.
    rng = np.random.default_rng(99)
    ips, labels = dataset.sample(ddos, ORACLE_BATCH, rng=rng)
    xp = jnp.asarray(dataset.ip_to_packed(ips))
    pop, signs = model.forward_packed(
        cfg.spec, [jnp.asarray(w) for w in packed], xp
    )
    golden = {
        "input_packed": [[int(v) for v in row] for row in np.asarray(xp)],
        "labels": [int(v) for v in labels],
        "final_popcount": [[int(v) for v in row] for row in np.asarray(pop)],
        "sign_packed": [
            [[int(v) for v in row] for row in np.asarray(s)] for s in signs
        ],
    }

    meta = {
        "format": "n2net-meta-v1",
        "oracle_batch": ORACLE_BATCH,
        "in_words": ref.n_words(cfg.spec.in_bits),
        # Weight parameters, in call order after x: [neurons, words] each.
        "weight_shapes": [
            [m, ref.n_words(n)] for (m, n) in cfg.spec.layer_shapes()
        ],
        "outputs": {
            "final_popcount": [ORACLE_BATCH, cfg.spec.layer_sizes[-1]],
            "sign_packed": [
                [ORACLE_BATCH, ref.n_words(m)] for m in cfg.spec.layer_sizes
            ],
        },
        "golden": golden,
    }
    mpath = os.path.join(out_dir, "meta.json")
    with open(mpath, "w") as f:
        json.dump(meta, f)
    if verbose:
        print(f"[aot] wrote {mpath}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the HLO artifact; siblings written next to it")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    cfg = train.TrainConfig()
    if args.steps is not None:
        cfg.steps = args.steps
    export(out_dir, cfg)


if __name__ == "__main__":
    main()
