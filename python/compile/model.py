"""L2: the N2Net BNN model — packed inference graph + float STE training graph.

Two views of the same network:

* ``forward_packed`` — the *deployment* forward pass: bit-packed uint32
  activations, XNOR-popcount-SIGN per layer via the L1 Pallas kernel
  (`kernels.binary_dense`). This is the function `aot.py` lowers to HLO
  text; the Rust runtime executes it via PJRT as the golden oracle for
  the switch-pipeline implementation.
* ``forward_float`` / ``loss_fn`` — the *training* surrogate: float
  weights, sign binarization with a straight-through estimator
  (BinaryNet, Courbariaux & Bengio 2016 — the paper's ref [4]). Ordinary
  matmuls, so XLA can use the MXU; only used at build time.

The BNN shapes follow the paper: every activation vector width must be a
power of two in [16, 2048] (Table 1's rows), because the switch-side
POPCNT tree and PHV layout assume it. The *output* of the last layer is
exempt (a classifier head may have 1 neuron).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import binary_dense as bd
from .kernels import ref

MIN_BITS = 16
MAX_BITS = 2048  # half the 512 B PHV, paper §2 Evaluation


@dataclasses.dataclass(frozen=True)
class BnnSpec:
    """Architecture of a fully-connected BNN.

    in_bits: width of the input activation vector (e.g. 32 for an IPv4
      destination address). layer_sizes: neurons per layer; each hidden
      layer's size becomes the next layer's activation width, so hidden
      sizes must be valid activation widths.
    """

    in_bits: int
    layer_sizes: tuple[int, ...]

    def __post_init__(self):
        widths = (self.in_bits, *self.layer_sizes[:-1])
        for w in widths:
            if not (MIN_BITS <= w <= MAX_BITS and (w & (w - 1)) == 0):
                raise ValueError(
                    f"activation width {w} invalid: must be a power of two "
                    f"in [{MIN_BITS}, {MAX_BITS}] (paper Table 1)"
                )
        if not self.layer_sizes:
            raise ValueError("need at least one layer")
        if self.layer_sizes[-1] < 1:
            raise ValueError("output layer needs >= 1 neuron")

    @property
    def n_layers(self) -> int:
        return len(self.layer_sizes)

    def layer_in_bits(self, i: int) -> int:
        return self.in_bits if i == 0 else self.layer_sizes[i - 1]

    def layer_shapes(self) -> list[tuple[int, int]]:
        """[(neurons, in_bits)] per layer."""
        return [(m, self.layer_in_bits(i)) for i, m in enumerate(self.layer_sizes)]

    def weight_bits_total(self) -> int:
        """Total weight storage in bits (what the element SRAM must hold)."""
        return sum(m * n for m, n in self.layer_shapes())


# ---------------------------------------------------------------------------
# Packed (deployment) forward
# ---------------------------------------------------------------------------

def init_packed_weights(
    spec: BnnSpec, seed: int = 0
) -> list[np.ndarray]:
    """Random packed weights, one [M, n_words(in_bits)] uint32 array/layer."""
    rng = np.random.default_rng(seed)
    out = []
    for m, n in spec.layer_shapes():
        w = rng.integers(0, 2**32, (m, ref.n_words(n)), dtype=np.uint32)
        w &= ref.word_masks(n)
        out.append(w)
    return out


def forward_packed(
    spec: BnnSpec,
    weights_packed: Sequence[jnp.ndarray],
    x_packed: jnp.ndarray,
    *,
    block_b: int = 128,
    block_m: int = 128,
):
    """Deployment forward pass on packed operands.

    Args:
      weights_packed: per-layer [M_l, W_l] uint32.
      x_packed: [B, W_0] uint32.

    Returns:
      (final_popcount [B, M_last] int32, layer_sign_bits: list of packed
      [B, n_words(M_l)] uint32 — one per layer, the exact bits the switch
      pipeline's folding step produces).
    """
    if len(weights_packed) != spec.n_layers:
        raise ValueError("weights/spec layer count mismatch")
    act = x_packed
    layer_signs_packed = []
    pop = None
    for i, wp in enumerate(weights_packed):
        n = spec.layer_in_bits(i)
        pop, sign = bd.binary_dense(
            act, wp, n_bits=n, block_b=block_b, block_m=block_m
        )
        sp = ref.pack_bits(sign, spec.layer_sizes[i])
        layer_signs_packed.append(sp)
        act = sp
    return pop, layer_signs_packed


# ---------------------------------------------------------------------------
# Float (training) forward — straight-through estimator
# ---------------------------------------------------------------------------

def ste_sign(x: jnp.ndarray) -> jnp.ndarray:
    """sign(x) in {-1,+1} forward (sign(0)=+1), identity-in-[-1,1] backward."""
    clipped = jnp.clip(x, -1.0, 1.0)
    binar = jnp.where(x >= 0, 1.0, -1.0)
    return clipped + jax.lax.stop_gradient(binar - clipped)


def init_float_params(spec: BnnSpec, key: jax.Array) -> list[jnp.ndarray]:
    """Glorot-ish float weights, one [M, n] array per layer."""
    params = []
    for m, n in spec.layer_shapes():
        key, sub = jax.random.split(key)
        params.append(jax.random.normal(sub, (m, n)) * (1.0 / np.sqrt(n)))
    return params


def forward_float(
    spec: BnnSpec, params: Sequence[jnp.ndarray], x_pm1: jnp.ndarray
) -> jnp.ndarray:
    """Training forward: x_pm1 [B, in_bits] in {-1,+1} -> logits [B, M_last].

    Hidden layers binarize weights and activations with the STE; the last
    layer binarizes weights only and returns the scaled pre-activation as
    the logit (standard BinaryNet head).
    """
    act = x_pm1
    for i, w in enumerate(params):
        wb = ste_sign(w)
        pre = act @ wb.T / np.sqrt(w.shape[1])
        if i < spec.n_layers - 1:
            act = ste_sign(pre)
        else:
            return pre
    raise AssertionError("unreachable")


def loss_fn(
    spec: BnnSpec,
    params: Sequence[jnp.ndarray],
    x_pm1: jnp.ndarray,
    y: jnp.ndarray,
) -> jnp.ndarray:
    """Binary logistic loss on the final neuron (y in {0,1}, [B])."""
    logits = forward_float(spec, params, x_pm1)[:, 0]
    ypm = y.astype(jnp.float32) * 2.0 - 1.0
    return jnp.mean(jax.nn.softplus(-ypm * logits))


def binarize_params(
    spec: BnnSpec, params: Sequence[jnp.ndarray]
) -> list[np.ndarray]:
    """Float params -> packed uint32 weights (the deployment artifact)."""
    out = []
    for (m, n), w in zip(spec.layer_shapes(), params):
        bits = (np.asarray(w) >= 0).astype(np.uint32)
        out.append(np.asarray(ref.pack_bits(jnp.asarray(bits), n), dtype=np.uint32))
    return out


def predict_packed(
    spec: BnnSpec, weights_packed: Sequence[jnp.ndarray], x_packed: jnp.ndarray
) -> jnp.ndarray:
    """Deployment-side class prediction: final layer sign bit 0. [B] uint32."""
    _, signs = forward_packed(spec, weights_packed, x_packed)
    return signs[-1][:, 0] & jnp.uint32(1)
