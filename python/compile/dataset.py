"""Synthetic DDoS blacklist workload (paper §1, use case 1).

The paper motivates N2Net with "large white/blacklist indexes for Denial
of Service protection": classify a packet from header bits (here the 32-bit
destination... source IPv4 address of the attacker) instead of enumerating
every address in a lookup table.

We synthesize a *structured* attacker population — a set of CIDR subnets
(botnets cluster in address space) plus per-address noise — so that a tiny
model can learn it but an exact-match table cannot compress it. The same
generator parameters are exported to `artifacts/weights.json` and re-read
by the Rust trace generator (`rust/src/net/tracegen.rs`), so the Python
training set and the Rust packet traces are drawn from the same
distribution (identical seeds produce identical label functions; the label
function itself is deterministic given the subnet list).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Subnet:
    """IPv4 CIDR block: `prefix` holds the network bits, left-aligned."""

    prefix: int  # u32, host bits zero
    prefix_len: int  # 0..32

    def contains(self, ip: np.ndarray) -> np.ndarray:
        if self.prefix_len == 0:
            return np.ones_like(ip, dtype=bool)
        mask = np.uint32(0xFFFFFFFF) << np.uint32(32 - self.prefix_len)
        return (ip & mask) == np.uint32(self.prefix)

    def to_json(self) -> dict:
        return {"prefix": int(self.prefix), "prefix_len": self.prefix_len}


@dataclasses.dataclass(frozen=True)
class DdosSpec:
    """Parameters of the synthetic blacklist distribution."""

    subnets: tuple[Subnet, ...]
    attack_fraction: float = 0.5
    seed: int = 1234

    def to_json(self) -> dict:
        return {
            "subnets": [s.to_json() for s in self.subnets],
            "attack_fraction": self.attack_fraction,
            "seed": self.seed,
        }


def default_spec(n_subnets: int = 12, seed: int = 1234) -> DdosSpec:
    """Random /12../20 attacker subnets — a few thousand to ~1M hosts each."""
    rng = np.random.default_rng(seed)
    subnets = []
    for _ in range(n_subnets):
        plen = int(rng.integers(12, 21))
        net = int(rng.integers(0, 2**32)) & (0xFFFFFFFF << (32 - plen))
        subnets.append(Subnet(prefix=net & 0xFFFFFFFF, prefix_len=plen))
    return DdosSpec(subnets=tuple(subnets), seed=seed)


def label_ips(spec: DdosSpec, ips: np.ndarray) -> np.ndarray:
    """1 = attacker (blacklisted), 0 = benign."""
    bad = np.zeros(ips.shape, dtype=bool)
    for s in spec.subnets:
        bad |= s.contains(ips)
    return bad.astype(np.uint32)


def sample(
    spec: DdosSpec, n: int, *, rng: np.random.Generator | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Draw `n` (ip, label) pairs: ~attack_fraction from attacker subnets.

    Returns (ips u32 [n], labels u32 [n]).
    """
    if rng is None:
        rng = np.random.default_rng(spec.seed)
    n_attack = int(n * spec.attack_fraction)
    n_benign = n - n_attack
    # Attackers: pick a subnet, randomize host bits.
    idx = rng.integers(0, len(spec.subnets), n_attack)
    ips_a = np.empty(n_attack, dtype=np.uint32)
    for i, s in enumerate(spec.subnets):
        sel = idx == i
        host_bits = 32 - s.prefix_len
        hosts = rng.integers(0, 2**host_bits, sel.sum(), dtype=np.uint64)
        ips_a[sel] = (np.uint32(s.prefix) | hosts.astype(np.uint32))
    # Benign: uniform, resampled out of attacker space (rejection).
    ips_b = rng.integers(0, 2**32, n_benign, dtype=np.uint32)
    for _ in range(16):
        bad = label_ips(spec, ips_b).astype(bool)
        if not bad.any():
            break
        ips_b[bad] = rng.integers(0, 2**32, int(bad.sum()), dtype=np.uint32)
    ips = np.concatenate([ips_a, ips_b])
    perm = rng.permutation(n)
    ips = ips[perm]
    return ips, label_ips(spec, ips)


def ip_to_pm1(ips: np.ndarray) -> np.ndarray:
    """u32 IPs -> [n, 32] float {-1,+1}, bit 0 (LSB) first — matches the
    packed-bit convention in kernels/ref.py."""
    shifts = np.arange(32, dtype=np.uint32)
    bits = (ips[:, None] >> shifts) & np.uint32(1)
    return bits.astype(np.float32) * 2.0 - 1.0


def ip_to_packed(ips: np.ndarray) -> np.ndarray:
    """u32 IPs -> [n, 1] packed uint32 (the IP *is* the packed vector)."""
    return ips.reshape(-1, 1).astype(np.uint32)
