"""L1 Pallas kernel: bit-packed XNOR-popcount-SIGN binary dense layer.

This is the compute hot-spot of the paper, expressed for TPU-style
execution (see DESIGN.md §Hardware-Adaptation):

* the switching chip evaluates a neuron by XNOR-ing a packed activation
  vector against packed weights held in element SRAM, then popcounting
  via the HAKMEM tree and thresholding (SIGN);
* the TPU analogue is a *lane-parallel SWAR kernel*: activations and
  weights packed 32 bits/uint32 word, XNOR on the VPU, the same HAKMEM
  reduction per word (constant 5-step SWAR instead of a data-dependent
  loop), then an integer threshold compare. No MXU — the arithmetic is
  bitwise, which maps to the vector unit.

Tiling: grid = (B / block_b, M / block_m); each program instance holds an
x-tile [block_b, W] and a w-tile [block_m, W] in VMEM and produces a
[block_b, block_m] popcount + sign tile. The packed-word axis W is kept
innermost and fully resident (W <= 64 words for the paper's largest
2048-bit activations).

``interpret=True`` always: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is established here and the AOT artifact lowers
through the same jaxpr.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

WORD = 32

def swar_popcount(v: jnp.ndarray) -> jnp.ndarray:
    """Per-element popcount of a uint32 array via the SWAR tree.

    Identical arithmetic shape to the switch pipeline's POPCNT step
    (mask/shift/add tree — HAKMEM 169 / Hacker's Delight 5-2), except the
    final two levels are fused by the multiply trick — the switch cannot
    multiply, the VPU can. Constants are Python ints so Pallas traces
    them as literals rather than captured consts.
    """
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & 0x55555555)
    v = (v & 0x33333333) + ((v >> 2) & 0x33333333)
    v = (v + (v >> 4)) & 0x0F0F0F0F
    return ((v * 0x01010101) >> 24).astype(jnp.int32)


def _binary_dense_kernel(x_ref, w_ref, masks_ref, pop_ref, sign_ref, *, thresh):
    """One (block_b, block_m) tile: XNOR -> SWAR popcount -> threshold."""
    x = x_ref[...]  # [bb, W] uint32
    w = w_ref[...]  # [bm, W] uint32
    masks = masks_ref[...]  # [W] uint32, tail-word validity
    # Broadcast XNOR over the (batch, neuron) cross product; mask the tail
    # word so padding bits never count.
    xnor = (~(x[:, None, :] ^ w[None, :, :])) & masks  # [bb, bm, W]
    pop = jnp.sum(swar_popcount(xnor), axis=-1)  # [bb, bm] int32
    pop_ref[...] = pop
    sign_ref[...] = (pop >= thresh).astype(jnp.uint32)


def _pad_to(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = a.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(a, pads)


@functools.partial(
    jax.jit, static_argnames=("n_bits", "block_b", "block_m", "interpret")
)
def binary_dense(
    x_packed: jnp.ndarray,
    w_packed: jnp.ndarray,
    *,
    n_bits: int,
    block_b: int = 128,
    block_m: int = 128,
    interpret: bool = True,
):
    """Binary dense layer on packed operands.

    Args:
      x_packed: [B, W] uint32 packed activations (W = ceil(n_bits/32)).
      w_packed: [M, W] uint32 packed weights, one row per neuron.
      n_bits: logical activation width (16..2048 in the paper).
      block_b / block_m: VMEM tile sizes; clamped to the padded problem.
      interpret: Pallas interpret mode (must stay True off-TPU).

    Returns:
      (popcount [B, M] int32, sign_bits [B, M] uint32).
    """
    if x_packed.ndim != 2 or w_packed.ndim != 2:
        raise ValueError("x_packed and w_packed must be rank-2 (packed)")
    nw = ref.n_words(n_bits)
    if x_packed.shape[1] != nw or w_packed.shape[1] != nw:
        raise ValueError(
            f"packed width mismatch: n_bits={n_bits} needs {nw} words, "
            f"got x:{x_packed.shape[1]} w:{w_packed.shape[1]}"
        )
    b, m = x_packed.shape[0], w_packed.shape[0]
    bb = min(block_b, max(b, 1))
    bm = min(block_m, max(m, 1))
    xp = _pad_to(x_packed.astype(jnp.uint32), 0, bb)
    wp = _pad_to(w_packed.astype(jnp.uint32), 0, bm)
    bp, mp = xp.shape[0], wp.shape[0]

    masks = jnp.asarray(ref.word_masks(n_bits))
    thresh = (n_bits + 1) // 2
    kernel = functools.partial(_binary_dense_kernel, thresh=thresh)

    pop, sign = pl.pallas_call(
        kernel,
        grid=(bp // bb, mp // bm),
        in_specs=[
            pl.BlockSpec((bb, nw), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, nw), lambda i, j: (j, 0)),
            pl.BlockSpec((nw,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, mp), jnp.int32),
            jax.ShapeDtypeStruct((bp, mp), jnp.uint32),
        ],
        interpret=interpret,
    )(xp, wp, masks)
    return pop[:b, :m], sign[:b, :m]


def binary_dense_sign(
    x_packed: jnp.ndarray,
    w_packed: jnp.ndarray,
    *,
    n_bits: int,
    **kw,
) -> jnp.ndarray:
    """Sign bits only — the layer output the switch pipeline folds."""
    _, sign = binary_dense(x_packed, w_packed, n_bits=n_bits, **kw)
    return sign


def vmem_footprint_bytes(block_b: int, block_m: int, n_bits: int) -> int:
    """Estimated VMEM residency of one program instance (DESIGN.md §9).

    x-tile + w-tile + xnor broadcast + two output tiles, 4 B each element.
    Used by the perf pass to keep tiles under the 16 MiB VMEM budget.
    """
    w = ref.n_words(n_bits)
    x_tile = block_b * w
    w_tile = block_m * w
    xnor = block_b * block_m * w
    outs = 2 * block_b * block_m
    return 4 * (x_tile + w_tile + xnor + outs)
