"""Pure-jnp correctness oracles for the N2Net binary-dense kernel.

Bit conventions (shared with the Rust side, see rust/src/bnn/bitpack.rs):

* A logical bit-vector of ``n_bits`` is packed little-endian into
  ``ceil(n_bits / 32)`` uint32 words: logical bit *i* lives in word
  ``i // 32`` at bit position ``i % 32``.
* Bit value 1 encodes +1, bit value 0 encodes -1 (BinaryNet convention).
* A binary-dense neuron computes ``sign(sum_i x_i * w_i)`` over +-1 values,
  which over bits is ``popcount(XNOR(x, w)) >= ceil(n_bits / 2)`` — the
  paper's SIGN step ("bigger or equal to half the length of the
  activations vector").

Everything here is deliberately written with the *dumbest possible*
jnp: unpack to individual bits, compare as floats. These functions are the
trusted baseline the Pallas kernel (and, transitively, the Rust RMT
pipeline and the PJRT artifact) are checked against.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

WORD = 32
_MASK32 = np.uint32(0xFFFFFFFF)


def n_words(n_bits: int) -> int:
    """Number of uint32 words needed to hold ``n_bits`` packed bits."""
    return (n_bits + WORD - 1) // WORD


def tail_mask(n_bits: int) -> np.uint32:
    """Mask of valid bits in the last packed word (all-ones if aligned)."""
    rem = n_bits % WORD
    if rem == 0:
        return _MASK32
    return np.uint32((1 << rem) - 1)


def word_masks(n_bits: int) -> np.ndarray:
    """Per-word validity masks, shape [n_words(n_bits)] uint32."""
    w = n_words(n_bits)
    m = np.full(w, _MASK32, dtype=np.uint32)
    m[-1] = tail_mask(n_bits)
    return m


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def pack_bits(bits: jnp.ndarray, n_bits: int | None = None) -> jnp.ndarray:
    """Pack a [..., n_bits] array of {0,1} into [..., n_words] uint32.

    Little-endian within each word: bits[..., 0] -> word 0, bit 0.
    """
    bits = jnp.asarray(bits, dtype=jnp.uint32)
    if n_bits is None:
        n_bits = bits.shape[-1]
    w = n_words(n_bits)
    pad = w * WORD - n_bits
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    bits = bits.reshape(bits.shape[:-1] + (w, WORD))
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


def unpack_bits(words: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Unpack [..., n_words] uint32 into [..., n_bits] of {0,1} uint32."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD,))
    return bits[..., :n_bits]


def bits_to_pm1(bits: jnp.ndarray) -> jnp.ndarray:
    """{0,1} -> {-1,+1} float32."""
    return jnp.asarray(bits, jnp.float32) * 2.0 - 1.0


def pm1_to_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Floats -> {0,1} uint32 (>= 0 maps to 1: sign(0) := +1 convention)."""
    return (jnp.asarray(x) >= 0).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# popcount oracle
# ---------------------------------------------------------------------------

def popcount_ref(words: jnp.ndarray) -> jnp.ndarray:
    """Per-word popcount via full bit-unpack. [..., w] uint32 -> int32."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return jnp.sum(bits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# binary dense oracles
# ---------------------------------------------------------------------------

def binary_dense_popcount_ref(
    x_packed: jnp.ndarray, w_packed: jnp.ndarray, n_bits: int
) -> jnp.ndarray:
    """XNOR-popcount pre-activation.

    x_packed: [B, w] uint32, w_packed: [M, w] uint32 -> [B, M] int32 with
    values in [0, n_bits]: the number of agreeing (+1*+1 or -1*-1) positions.
    """
    masks = jnp.asarray(word_masks(n_bits))
    xnor = ~(x_packed[:, None, :] ^ w_packed[None, :, :]) & masks
    return jnp.sum(popcount_ref(xnor), axis=-1).astype(jnp.int32)


def binary_dense_ref(
    x_packed: jnp.ndarray, w_packed: jnp.ndarray, n_bits: int
) -> jnp.ndarray:
    """Full binary dense layer on packed operands -> sign bits [B, M] uint32.

    y_j = 1  iff  popcount(xnor) >= ceil(n_bits / 2).
    """
    pop = binary_dense_popcount_ref(x_packed, w_packed, n_bits)
    thresh = (n_bits + 1) // 2
    return (pop >= thresh).astype(jnp.uint32)


def binary_dense_float_ref(
    x_bits: jnp.ndarray, w_bits: jnp.ndarray
) -> jnp.ndarray:
    """The same layer computed in +-1 float arithmetic (textbook BinaryNet).

    x_bits: [B, n] {0,1}, w_bits: [M, n] {0,1} -> sign bits [B, M] uint32.
    sign(sum x*w) with sign(0) := +1; equals the packed path for even n
    (the paper's sizes are all powers of two) and for odd n both sides use
    the >= ceil(n/2) threshold, which is the same predicate.
    """
    acc = bits_to_pm1(x_bits) @ bits_to_pm1(w_bits).T
    return pm1_to_bits(acc)
