"""Build-time BNN training (straight-through estimator, hand-rolled Adam).

Trains the paper's use-case model — a fully-connected BNN over the 32-bit
IP activation vector (§2 Evaluation: "e.g., the destination IP address of
the packet", layers of 64 and 32 neurons) plus a 1-neuron readout — on the
synthetic DDoS blacklist task, then binarizes and packs the weights for
the N2Net compiler.

Runs only under `make artifacts`; nothing here is on the request path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, model


@dataclasses.dataclass
class TrainConfig:
    spec: model.BnnSpec = dataclasses.field(
        default_factory=lambda: model.BnnSpec(in_bits=32, layer_sizes=(64, 32, 1))
    )
    n_train: int = 16384
    n_test: int = 4096
    batch_size: int = 256
    steps: int = 1500
    lr: float = 3e-3
    seed: int = 7


def adam_init(params: Sequence[jnp.ndarray]):
    zeros = [jnp.zeros_like(p) for p in params]
    return {"m": zeros, "v": [jnp.zeros_like(p) for p in params], "t": jnp.int32(0)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = [b1 * m_ + (1 - b1) * g for m_, g in zip(state["m"], grads)]
    v = [b2 * v_ + (1 - b2) * g * g for v_, g in zip(state["v"], grads)]
    mhat = [m_ / (1 - b1**t) for m_ in m]
    vhat = [v_ / (1 - b2**t) for v_ in v]
    new_params = [
        p - lr * mh / (jnp.sqrt(vh) + eps) for p, mh, vh in zip(params, mhat, vhat)
    ]
    return new_params, {"m": m, "v": v, "t": t}


def train(
    cfg: TrainConfig, ddos: dataset.DdosSpec | None = None, verbose: bool = True
):
    """Returns (float params, packed weights, metrics dict)."""
    if ddos is None:
        ddos = dataset.default_spec(seed=cfg.seed * 31 + 3)
    rng = np.random.default_rng(cfg.seed)
    ips_tr, y_tr = dataset.sample(ddos, cfg.n_train, rng=rng)
    ips_te, y_te = dataset.sample(ddos, cfg.n_test, rng=rng)
    x_tr = dataset.ip_to_pm1(ips_tr)
    x_te = dataset.ip_to_pm1(ips_te)

    key = jax.random.PRNGKey(cfg.seed)
    params = model.init_float_params(cfg.spec, key)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg.spec, p, xb, yb)
        )(params)
        params, opt = adam_update(params, grads, opt, cfg.lr)
        return params, opt, loss

    n = x_tr.shape[0]
    losses = []
    for i in range(cfg.steps):
        idx = rng.integers(0, n, cfg.batch_size)
        params, opt, loss = step(
            params, opt, jnp.asarray(x_tr[idx]), jnp.asarray(y_tr[idx])
        )
        losses.append(float(loss))
        if verbose and (i % 250 == 0 or i == cfg.steps - 1):
            print(f"  step {i:5d}  loss {float(loss):.4f}")

    # Deployment metrics come from the *packed* model — the thing that
    # actually ships to the switch — not the float surrogate.
    packed = model.binarize_params(cfg.spec, params)
    pk = [jnp.asarray(w) for w in packed]
    pred_tr = np.asarray(
        model.predict_packed(cfg.spec, pk, jnp.asarray(dataset.ip_to_packed(ips_tr)))
    )
    pred_te = np.asarray(
        model.predict_packed(cfg.spec, pk, jnp.asarray(dataset.ip_to_packed(ips_te)))
    )
    acc_tr = float((pred_tr == y_tr).mean())
    acc_te = float((pred_te == y_te).mean())
    metrics = {
        "train_accuracy_packed": acc_tr,
        "test_accuracy_packed": acc_te,
        "final_loss": losses[-1],
        "loss_curve": losses[:: max(1, len(losses) // 100)],
        "steps": cfg.steps,
    }
    if verbose:
        print(f"  packed accuracy: train {acc_tr:.4f}  test {acc_te:.4f}")
    return params, packed, metrics, ddos


if __name__ == "__main__":
    train(TrainConfig())
