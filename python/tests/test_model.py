"""L2 model tests: spec validation, packed forward, STE training graph."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import dataset, model
from compile.kernels import ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# spec validation (mirrors rust/src/bnn/model.rs)
# ---------------------------------------------------------------------------

def test_spec_validation():
    model.BnnSpec(in_bits=32, layer_sizes=(64, 32, 1))  # ok
    model.BnnSpec(in_bits=2048, layer_sizes=(1,))  # ok
    with pytest.raises(ValueError):
        model.BnnSpec(in_bits=48, layer_sizes=(16,))  # not pow2
    with pytest.raises(ValueError):
        model.BnnSpec(in_bits=8, layer_sizes=(16,))  # below min
    with pytest.raises(ValueError):
        model.BnnSpec(in_bits=4096, layer_sizes=(16,))  # above max
    with pytest.raises(ValueError):
        model.BnnSpec(in_bits=32, layer_sizes=(48, 16))  # bad hidden width
    with pytest.raises(ValueError):
        model.BnnSpec(in_bits=32, layer_sizes=())


def test_layer_shapes_and_weight_bits():
    spec = model.BnnSpec(in_bits=32, layer_sizes=(64, 32, 1))
    assert spec.layer_shapes() == [(64, 32), (32, 64), (1, 32)]
    assert spec.weight_bits_total() == 64 * 32 + 32 * 64 + 32


# ---------------------------------------------------------------------------
# packed forward
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**32 - 1))
def test_forward_packed_layerwise_equals_manual(seed):
    spec = model.BnnSpec(in_bits=32, layer_sizes=(16, 16))
    wts = [jnp.asarray(w) for w in model.init_packed_weights(spec, seed=seed % 1000)]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2**32, (4, 1), dtype=np.uint32))
    pop, signs = model.forward_packed(spec, wts, x)
    # Manual layer-by-layer with the oracle.
    act = x
    for i, w in enumerate(wts):
        n = spec.layer_in_bits(i)
        s = ref.binary_dense_ref(act, w, n)
        sp = ref.pack_bits(s, spec.layer_sizes[i])
        np.testing.assert_array_equal(np.asarray(signs[i]), np.asarray(sp))
        act = sp
    # Final popcount from the oracle too.
    expect_pop = ref.binary_dense_popcount_ref(signs[0], wts[1], 16)
    np.testing.assert_array_equal(np.asarray(pop), np.asarray(expect_pop))


def test_predict_packed_is_final_bit():
    spec = model.BnnSpec(in_bits=32, layer_sizes=(16, 1))
    wts = [jnp.asarray(w) for w in model.init_packed_weights(spec, seed=3)]
    x = jnp.asarray(np.arange(8, dtype=np.uint32).reshape(-1, 1))
    pred = model.predict_packed(spec, wts, x)
    _, signs = model.forward_packed(spec, wts, x)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(signs[-1][:, 0] & 1))


def test_forward_packed_rejects_mismatched_weights():
    spec = model.BnnSpec(in_bits=32, layer_sizes=(16, 1))
    wts = [jnp.asarray(w) for w in model.init_packed_weights(spec, seed=3)]
    with pytest.raises(ValueError):
        model.forward_packed(spec, wts[:1], jnp.zeros((2, 1), jnp.uint32))


# ---------------------------------------------------------------------------
# STE training graph
# ---------------------------------------------------------------------------

def test_ste_sign_forward_and_gradient():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    y = model.ste_sign(x)
    np.testing.assert_array_equal(np.asarray(y), [-1, -1, 1, 1, 1])
    # Straight-through: gradient is identity inside [-1,1], zero outside.
    g = jax.grad(lambda v: model.ste_sign(v).sum())(x)
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 1.0, 0.0])


def test_binarize_params_matches_float_signs():
    spec = model.BnnSpec(in_bits=32, layer_sizes=(16,))
    key = jax.random.PRNGKey(0)
    params = model.init_float_params(spec, key)
    packed = model.binarize_params(spec, params)
    bits = ref.unpack_bits(jnp.asarray(packed[0]), 32)
    np.testing.assert_array_equal(
        np.asarray(bits), (np.asarray(params[0]) >= 0).astype(np.uint32)
    )


def test_float_and_packed_forward_agree_after_binarization():
    """The deployment (packed) model equals the float model evaluated
    with hard-binarized weights/activations."""
    spec = model.BnnSpec(in_bits=32, layer_sizes=(16, 1))
    key = jax.random.PRNGKey(1)
    params = model.init_float_params(spec, key)
    packed = [jnp.asarray(w) for w in model.binarize_params(spec, params)]
    rng = np.random.default_rng(2)
    ips = rng.integers(0, 2**32, 32, dtype=np.uint32)
    x_packed = jnp.asarray(dataset.ip_to_packed(ips))
    pred_packed = np.asarray(model.predict_packed(spec, packed, x_packed))
    # Float path with hard sign at every stage.
    x = jnp.asarray(dataset.ip_to_pm1(ips))
    act = x
    for i, w in enumerate(params):
        wb = np.where(np.asarray(w) >= 0, 1.0, -1.0)
        pre = act @ wb.T
        if i < spec.n_layers - 1:
            act = jnp.where(pre >= 0, 1.0, -1.0)
        else:
            pred_float = (np.asarray(pre[:, 0]) >= 0).astype(np.uint32)
    np.testing.assert_array_equal(pred_packed, pred_float)


def test_training_reduces_loss():
    from compile import train

    cfg = train.TrainConfig(steps=120, n_train=2048, n_test=512, seed=5)
    _params, packed, metrics, _ddos = train.train(cfg, verbose=False)
    assert metrics["final_loss"] < 0.7  # below chance-level logloss
    assert metrics["test_accuracy_packed"] > 0.6
    assert len(packed) == 3
    assert packed[0].shape == (64, 1)
