"""Synthetic DDoS distribution tests (mirrored by rust net/tracegen)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import dataset

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def test_subnet_containment():
    s = dataset.Subnet(prefix=0xC0A80000, prefix_len=16)  # 192.168/16
    ips = np.array([0xC0A80001, 0xC0A8FFFF, 0xC0A90000, 0x01020304], dtype=np.uint32)
    np.testing.assert_array_equal(s.contains(ips), [True, True, False, False])


def test_zero_length_prefix_matches_all():
    s = dataset.Subnet(prefix=0, prefix_len=0)
    assert s.contains(np.array([0, 2**32 - 1], dtype=np.uint32)).all()


@given(seed=st.integers(0, 2**31 - 1))
def test_sample_labels_are_ground_truth(seed):
    spec = dataset.default_spec(n_subnets=6, seed=seed)
    ips, labels = dataset.sample(spec, 500, rng=np.random.default_rng(seed))
    np.testing.assert_array_equal(labels, dataset.label_ips(spec, ips))


def test_attack_fraction_respected():
    spec = dataset.default_spec(seed=3)
    _ips, labels = dataset.sample(spec, 4000)
    frac = labels.mean()
    # Rejection sampling of benign IPs can only leave attackers at ~50%.
    assert 0.42 <= frac <= 0.58, frac


def test_ip_bit_encoding_consistency():
    ips = np.array([0b1011, 1 << 31], dtype=np.uint32)
    pm1 = dataset.ip_to_pm1(ips)
    # bit 0 first (LSB-first, matching the packed-word convention).
    np.testing.assert_array_equal(pm1[0, :4], [1.0, 1.0, -1.0, 1.0])
    assert pm1[1, 31] == 1.0 and pm1[1, 0] == -1.0
    packed = dataset.ip_to_packed(ips)
    np.testing.assert_array_equal(packed[:, 0], ips)


def test_spec_json_roundtrip_fields():
    spec = dataset.default_spec(n_subnets=4, seed=9)
    doc = spec.to_json()
    assert len(doc["subnets"]) == 4
    for s in doc["subnets"]:
        assert 12 <= s["prefix_len"] <= 20
        # host bits must be zero in the stored prefix
        mask = (0xFFFFFFFF << (32 - s["prefix_len"])) & 0xFFFFFFFF
        assert s["prefix"] & ~mask == 0
