"""AOT export tests: HLO text sanity and artifact consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, dataset, model
from compile.kernels import ref

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def small_spec():
    return model.BnnSpec(in_bits=32, layer_sizes=(16, 1))


def test_lower_forward_uses_parameters_not_constants():
    """The weights MUST be HLO parameters: the text printer elides large
    constants (`constant({...})`), which the old XLA parser would read
    back as garbage (this bug was found the hard way — see aot.py)."""
    hlo = aot.lower_forward(small_spec(), batch=8)
    assert "constant({...}" not in hlo
    # ENTRY takes x + one parameter per layer.
    entry = hlo[hlo.index("ENTRY") :]
    first_block = entry[: entry.index("ROOT")]
    assert first_block.count("parameter(") == 1 + 2


def test_lowered_hlo_shapes():
    hlo = aot.lower_forward(small_spec(), batch=8)
    assert "u32[8,1]" in hlo  # x packed
    assert "u32[16,1]" in hlo  # layer 0 weights
    assert "u32[1,1]" in hlo  # layer 1 weights
    assert "s32[8,1]" in hlo  # final popcount


def test_export_and_reload(tmp_path):
    from compile import train

    cfg = train.TrainConfig(steps=30, n_train=1024, n_test=256, seed=11)
    out = str(tmp_path)
    aot.export(out, cfg, verbose=False)
    for f in ["weights.json", "model.hlo.txt", "meta.json"]:
        assert os.path.exists(os.path.join(out, f)), f

    weights = json.load(open(os.path.join(out, "weights.json")))
    assert weights["format"] == "n2net-weights-v1"
    assert [l["neurons"] for l in weights["layers"]] == [64, 32, 1]

    meta = json.load(open(os.path.join(out, "meta.json")))
    assert meta["oracle_batch"] == aot.ORACLE_BATCH
    assert meta["weight_shapes"] == [[64, 1], [32, 2], [1, 1]]

    # Golden vectors recompute identically from the stored weights.
    spec = model.BnnSpec(
        in_bits=weights["spec"]["in_bits"],
        layer_sizes=tuple(weights["spec"]["layer_sizes"]),
    )
    wts = [
        jnp.asarray(np.array(l["weights_packed"], dtype=np.uint32))
        for l in weights["layers"]
    ]
    g = meta["golden"]
    x = jnp.asarray(np.array(g["input_packed"], dtype=np.uint32))
    pop, signs = model.forward_packed(spec, wts, x)
    np.testing.assert_array_equal(np.asarray(pop), np.array(g["final_popcount"]))
    for got, expect in zip(signs, g["sign_packed"]):
        np.testing.assert_array_equal(np.asarray(got), np.array(expect))


def test_real_artifacts_consistent_if_present():
    """When `make artifacts` has run, the checked-in goldens must agree
    with a fresh recomputation (guards against stale artifacts)."""
    wpath = os.path.join(ARTIFACTS, "weights.json")
    mpath = os.path.join(ARTIFACTS, "meta.json")
    if not (os.path.exists(wpath) and os.path.exists(mpath)):
        import pytest

        pytest.skip("artifacts not built")
    weights = json.load(open(wpath))
    meta = json.load(open(mpath))
    spec = model.BnnSpec(
        in_bits=weights["spec"]["in_bits"],
        layer_sizes=tuple(weights["spec"]["layer_sizes"]),
    )
    wts = [
        jnp.asarray(np.array(l["weights_packed"], dtype=np.uint32))
        for l in weights["layers"]
    ]
    g = meta["golden"]
    x = jnp.asarray(np.array(g["input_packed"], dtype=np.uint32))
    pop, _signs = model.forward_packed(spec, wts, x)
    np.testing.assert_array_equal(np.asarray(pop), np.array(g["final_popcount"]))
    # Labels in the golden block match the stored DDoS distribution.
    d = weights["ddos"]
    subnets = [
        dataset.Subnet(prefix=s["prefix"], prefix_len=s["prefix_len"])
        for s in d["subnets"]
    ]
    spec_d = dataset.DdosSpec(
        subnets=tuple(subnets),
        attack_fraction=d["attack_fraction"],
        seed=d["seed"],
    )
    ips = np.array([row[0] for row in g["input_packed"]], dtype=np.uint32)
    np.testing.assert_array_equal(
        dataset.label_ips(spec_d, ips), np.array(g["labels"], dtype=np.uint32)
    )


def test_hlo_text_deterministic():
    a = aot.lower_forward(small_spec(), batch=4)
    b = aot.lower_forward(small_spec(), batch=4)
    assert a == b
