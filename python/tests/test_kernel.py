"""L1 kernel correctness: Pallas binary_dense vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path — hypothesis
sweeps shapes, widths and block sizes; every case must match ref.py
exactly (integer outputs, no tolerance).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary_dense as bd
from compile.kernels import ref

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


def rand_packed(rng, rows, n_bits):
    w = ref.n_words(n_bits)
    x = rng.integers(0, 2**32, (rows, w), dtype=np.uint32)
    return x & ref.word_masks(n_bits)


# ---------------------------------------------------------------------------
# swar popcount
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
def test_swar_popcount_matches_bit_count(words):
    arr = jnp.asarray(np.array(words, dtype=np.uint32))
    got = np.asarray(bd.swar_popcount(arr))
    expect = np.array([bin(w).count("1") for w in words], dtype=np.int32)
    np.testing.assert_array_equal(got, expect)


def test_swar_popcount_extremes():
    arr = jnp.asarray(np.array([0, 0xFFFFFFFF, 0x80000000, 1], dtype=np.uint32))
    np.testing.assert_array_equal(np.asarray(bd.swar_popcount(arr)), [0, 32, 1, 1])


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

@given(
    st.integers(1, 100),
    st.integers(0, 2**64 - 1),
)
def test_pack_unpack_roundtrip(n_bits, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (3, n_bits), dtype=np.uint32)
    packed = ref.pack_bits(jnp.asarray(bits), n_bits)
    back = ref.unpack_bits(packed, n_bits)
    np.testing.assert_array_equal(np.asarray(back), bits)


def test_pack_layout_little_endian():
    # bit 0 -> word 0 bit 0; bit 33 -> word 1 bit 1.
    bits = np.zeros(64, dtype=np.uint32)
    bits[0] = 1
    bits[33] = 1
    packed = np.asarray(ref.pack_bits(jnp.asarray(bits), 64))
    assert packed[0] == 1
    assert packed[1] == 2


# ---------------------------------------------------------------------------
# binary dense kernel vs oracle
# ---------------------------------------------------------------------------

@given(
    n_bits=st.sampled_from([16, 32, 48, 64, 128, 256, 2048]),
    batch=st.integers(1, 9),
    neurons=st.integers(1, 17),
    block_b=st.sampled_from([2, 4, 128]),
    block_m=st.sampled_from([3, 8, 128]),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_oracle(n_bits, batch, neurons, block_b, block_m, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rand_packed(rng, batch, n_bits))
    w = jnp.asarray(rand_packed(rng, neurons, n_bits))
    pop, sign = bd.binary_dense(x, w, n_bits=n_bits, block_b=block_b, block_m=block_m)
    np.testing.assert_array_equal(
        np.asarray(pop), np.asarray(ref.binary_dense_popcount_ref(x, w, n_bits))
    )
    np.testing.assert_array_equal(
        np.asarray(sign), np.asarray(ref.binary_dense_ref(x, w, n_bits))
    )


@given(
    n_bits=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_float_semantics(n_bits, seed):
    """Packed XNOR-popcount-sign == textbook ±1 BinaryNet layer."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rand_packed(rng, 5, n_bits))
    w = jnp.asarray(rand_packed(rng, 7, n_bits))
    sign = bd.binary_dense_sign(x, w, n_bits=n_bits)
    xb = ref.unpack_bits(x, n_bits)
    wb = ref.unpack_bits(w, n_bits)
    np.testing.assert_array_equal(
        np.asarray(sign), np.asarray(ref.binary_dense_float_ref(xb, wb))
    )


def test_kernel_identity_and_inverse_weights():
    # Weight row == input -> full agreement (popcount = n, fires).
    # Weight row == ~input -> zero agreement (does not fire).
    rng = np.random.default_rng(0)
    x = rand_packed(rng, 1, 64)
    w = np.concatenate([x, ~x & ref.word_masks(64)], axis=0)
    pop, sign = bd.binary_dense(jnp.asarray(x), jnp.asarray(w), n_bits=64)
    np.testing.assert_array_equal(np.asarray(pop), [[64, 0]])
    np.testing.assert_array_equal(np.asarray(sign), [[1, 0]])


def test_threshold_tie_fires():
    # popcount == ceil(n/2) must fire (sign(0) := +1, paper's ">= half").
    n = 32
    # Agreement on exactly 16 bits.
    x = np.array([[0x0000FFFF]], dtype=np.uint32)
    w = np.array([[0xFFFFFFFF]], dtype=np.uint32)
    pop, sign = bd.binary_dense(jnp.asarray(x), jnp.asarray(w), n_bits=n)
    assert np.asarray(pop)[0, 0] == 16
    assert np.asarray(sign)[0, 0] == 1


def test_wrong_width_raises():
    x = jnp.zeros((2, 2), jnp.uint32)
    w = jnp.zeros((3, 1), jnp.uint32)
    with pytest.raises(ValueError):
        bd.binary_dense(x, w, n_bits=32)
    with pytest.raises(ValueError):
        bd.binary_dense(jnp.zeros((2,), jnp.uint32), w, n_bits=32)


def test_vmem_footprint_model():
    # DESIGN.md §9: default tiles stay within the 16 MiB VMEM budget for
    # the paper's largest activation width.
    assert bd.vmem_footprint_bytes(128, 128, 2048) <= 16 * 2**20
    assert bd.vmem_footprint_bytes(128, 128, 32) < bd.vmem_footprint_bytes(128, 128, 2048)
