//! Quickstart: compile a BNN to a switch pipeline and classify a packet.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::compiler::{Compiler, CompilerOptions};
use n2net::net::packet::PacketBuilder;
use n2net::rmt::{ChipConfig, Pipeline};

fn main() -> anyhow::Result<()> {
    // 1. A BNN over 32-bit activations — the paper's use-case shape:
    //    two layers of 64 and 32 neurons (§2 Evaluation).
    let model = BnnModel::random(32, &[64, 32], 42);
    println!(
        "model: {}b input -> {:?} ({} weight bits)",
        model.spec.in_bits,
        model.spec.layer_sizes,
        model.spec.weight_bits_total()
    );

    // 2. Compile it for an RMT switching chip. The activations are read
    //    from the packet payload (after Eth+IPv4+UDP).
    let chip = ChipConfig::rmt();
    let compiled = Compiler::new(chip.clone(), CompilerOptions::default())
        .compile(&model)?;
    println!("\n{}", compiled.resource_report());

    // 3. Build a real packet carrying the activation vector and push it
    //    through the simulated pipeline.
    let activations = 0xDEADBEEFu32;
    let frame = PacketBuilder::default().build_activations(&[activations]);
    let mut pipe = Pipeline::new(
        chip,
        compiled.program.clone(),
        compiled.parser.clone(),
        false, // paper-sized model: must fit a single pass
    )?;
    let phv = pipe.process_packet(&frame)?;
    let out = compiled.read_output(&phv);
    println!("input activations: {activations:#010x}");
    println!("switch output bits: {:?}", out.to_bits());

    // 4. The pipeline result is bit-exact with the reference forward.
    let expect = bnn::forward(&model, &PackedBits::from_u32(activations));
    assert_eq!(out, expect, "pipeline must match the reference forward");
    println!("reference forward agrees bit-for-bit ✓");

    // 5. Line-rate model: what the ASIC would sustain.
    let t = pipe.timing();
    println!(
        "modeled ASIC: {:.0} M inferences/s, {:.1} ns pipeline latency \
         ({} elements, {} pass)",
        t.pps / 1e6,
        t.latency_ns,
        t.elements,
        t.passes
    );
    Ok(())
}
