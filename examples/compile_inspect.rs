//! Figure 2 reproduction: the per-element schedule of a 3-neuron BNN.
//!
//! The paper's Fig. 2 walks a 3-neuron BNN through the five steps
//! (Replication; XNOR and Duplication; POPCNT; SIGN; Folding). This
//! example compiles exactly that model and prints the emitted element
//! schedule plus the generated P4-like description.
//!
//! ```bash
//! cargo run --release --example compile_inspect
//! ```

use n2net::bnn::BnnModel;
use n2net::compiler::{p4gen, Compiler, CompilerOptions, InputEncoding};
use n2net::rmt::ChipConfig;

fn main() -> anyhow::Result<()> {
    // Fig. 2's example: 3 neurons over one activation vector. We use
    // 32-bit activations (the paper's running example width).
    let model = BnnModel::random(32, &[3], 2018);
    let opts = CompilerOptions {
        input: InputEncoding::PayloadLe { offset: 0 },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts).compile(&model)?;

    println!("=== Fig. 2: 3-neuron BNN, five processing steps ===\n");
    print!("{}", compiled.program.schedule_listing());
    println!();
    print!("{}", compiled.resource_report());

    println!("\n=== element micro-ops (first two elements) ===");
    for e in compiled.program.elements.iter().take(2) {
        println!("[{}] {}", e.step.name(), e.label);
        for op in &e.ops {
            println!("    {op}");
        }
    }

    println!("\n=== generated P4 description (truncated) ===");
    let p4 = p4gen::render(&compiled.program, &compiled.parser, "fig2-3neuron");
    for line in p4.lines().take(40) {
        println!("{line}");
    }
    println!("... ({} lines total)", p4.lines().count());

    // The five steps of Fig. 2, in order.
    let steps: Vec<&str> = compiled
        .program
        .elements
        .iter()
        .map(|e| e.step.name())
        .collect();
    assert_eq!(steps.first(), Some(&"Replication"));
    assert_eq!(steps.get(1), Some(&"XNOR+Duplication"));
    assert!(steps.iter().any(|s| s.starts_with("POPCNT")));
    assert_eq!(steps[steps.len() - 2], "SIGN");
    assert_eq!(steps[steps.len() - 1], "Folding");
    println!("\nfive-step structure verified ✓");
    Ok(())
}
