//! End-to-end driver: the full three-layer stack on a real workload.
//!
//!   JAX/Pallas training (build time, `make artifacts`)
//!     → packed weights + AOT HLO artifact
//!     → `deploy::Deployment` (N2Net compiler → RMT pipeline program)
//!     → simulated switch serves a 50k-packet DDoS trace (multi-worker
//!       engine over the deployment's publication slot, then the
//!       sharded flow-affinity tier cross-checked bit-exact against it)
//!     → every output cross-checked bit-for-bit against (a) the Rust
//!       reference forward and (b) the PJRT-executed JAX model
//!     → accuracy / throughput / latency / memory report.
//!
//! Results are recorded in EXPERIMENTS.md §E9.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::time::Instant;

use n2net::bnn::{self, PackedBits};
use n2net::baseline::LutClassifier;
use n2net::coordinator::RouterPolicy;
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::net::{Scenario, TraceGenerator, TraceKind};
use n2net::runtime::Oracle;
use n2net::util::rng::Rng;

const N_PACKETS: usize = 50_000;
const ORACLE_SAMPLE: usize = 512;

fn main() -> anyhow::Result<()> {
    println!("=== N2Net end-to-end: train → compile → serve → verify ===\n");

    // ---- 1. Build-time artifacts (JAX/Pallas, STE training) ----------
    let dir = Oracle::default_dir();
    let (model, doc) = bnn::load_weights(dir.join("weights.json"))?;
    println!("[1] trained BNN: {}b -> {:?}", model.spec.in_bits, model.spec.layer_sizes);
    println!(
        "    training: {} steps, final loss {:.4}, packed accuracy train {:.2}% / test {:.2}%",
        doc.metrics.steps,
        doc.metrics.final_loss,
        doc.metrics.train_accuracy_packed * 100.0,
        doc.metrics.test_accuracy_packed * 100.0
    );
    if !doc.metrics.loss_curve.is_empty() {
        let c = &doc.metrics.loss_curve;
        let probe: Vec<String> = [0, c.len() / 4, c.len() / 2, 3 * c.len() / 4, c.len() - 1]
            .iter()
            .map(|&i| format!("{:.3}", c[i]))
            .collect();
        println!("    loss curve (0%..100%): {}", probe.join(" → "));
    }

    // ---- 2. Deploy onto the switch -----------------------------------
    let n_workers = std::thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(4);
    let deployment = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .router(RouterPolicy::RoundRobin)
        .workers(n_workers)
        .model("e2e", model.clone())
        .build()?;
    println!("\n[2] deployed to RMT pipeline (model v{}):", deployment.version("e2e")?);
    for line in deployment.compiled("e2e")?.resource_report().lines() {
        println!("    {line}");
    }

    // ---- 3. Serve a DDoS trace through the engine --------------------
    let mut gen = TraceGenerator::new(2026);
    let trace = gen.generate(&TraceKind::Ddos { ddos: doc.ddos.clone() }, N_PACKETS);
    let engine = deployment.engine("e2e")?;
    let t0 = Instant::now();
    let report = engine.process_trace(&trace.packets)?;
    let wall = t0.elapsed();
    println!(
        "\n[3] served {} packets with {n_workers} workers ({} backend) in {:.2?}",
        N_PACKETS, report.backend, wall
    );
    println!(
        "    host simulator: {:.2} M packets/s | modeled ASIC: {:.0} M packets/s",
        report.sim_pps / 1e6,
        report.modeled_pps / 1e6
    );
    println!("    {}", engine.metrics.batch_latency.render("worker-shard latency"));

    // ---- 3b. Sharded tier: flow-affinity dispatch, bit-exact ---------
    let sharded = deployment.sharded_engine("e2e", 4)?;
    let sreport = sharded.process_trace(&trace.packets)?;
    anyhow::ensure!(
        sreport.outputs == report.outputs,
        "sharded serving diverged from the engine"
    );
    println!(
        "\n[3b] sharded x{}: {:.2} M pkt/s aggregate, imbalance {:.2}, \
         dropped {}, versions v{}..v{} (≡ engine outputs ✓)",
        sreport.per_shard.len(),
        sreport.sim_pps / 1e6,
        sreport.imbalance(),
        sreport.dropped,
        sreport.version_min,
        sreport.version_max,
    );
    // A skewed scenario: the zipf heavy hitter pins its flow to one
    // shard (that is what flow affinity costs — and buys: per-flow
    // state never splits across shards).
    let hh = Scenario::parse("zipf-heavy-hitter")?.generate(5, 20_000);
    let hh_report = sharded.process_trace(&hh.packets)?;
    println!(
        "     zipf-heavy-hitter: {:.2} M pkt/s, imbalance {:.2}",
        hh_report.sim_pps / 1e6,
        hh_report.imbalance(),
    );

    // ---- 4. Verification: three implementations, one answer ----------
    // 4a. Rust reference forward on every packet.
    let t_ref = Instant::now();
    let mut ref_mismatch = 0usize;
    for (i, &key) in trace.keys.iter().enumerate() {
        let expect = bnn::forward(&model, &PackedBits::from_u32(key)).get(0) as u32;
        if expect != report.outputs[i] {
            ref_mismatch += 1;
        }
    }
    println!(
        "\n[4] verification: switch vs Rust reference: {}/{} agree ({:.2?})",
        N_PACKETS - ref_mismatch,
        N_PACKETS,
        t_ref.elapsed()
    );
    anyhow::ensure!(ref_mismatch == 0, "pipeline diverged from reference");

    // 4b. PJRT oracle (AOT-compiled JAX/Pallas model) on a sample.
    let oracle = Oracle::load(&dir)?;
    oracle.self_test()?;
    let mut rng = Rng::seed_from_u64(77);
    let idx: Vec<usize> = (0..ORACLE_SAMPLE).map(|_| rng.gen_range(0, N_PACKETS)).collect();
    let sample: Vec<Vec<u32>> = idx.iter().map(|&i| vec![trace.keys[i]]).collect();
    let oracle_bits = oracle.classify(&sample)?;
    let agree = idx
        .iter()
        .zip(&oracle_bits)
        .filter(|(&i, &b)| report.outputs[i] == b)
        .count();
    println!(
        "    switch vs PJRT oracle (JAX/Pallas via HLO text): {agree}/{ORACLE_SAMPLE} agree"
    );
    anyhow::ensure!(agree == ORACLE_SAMPLE, "pipeline diverged from AOT oracle");

    // ---- 5. Task metrics ---------------------------------------------
    let correct = report
        .outputs
        .iter()
        .zip(&trace.labels)
        .filter(|(p, l)| p == l)
        .count();
    let acc = correct as f64 / N_PACKETS as f64;
    println!("\n[5] DDoS classification accuracy on the live trace: {:.2}%", acc * 100.0);

    // Memory story vs the LUT baseline at equal SRAM.
    let weight_bits = model.spec.weight_bits_total();
    let mut lut = LutClassifier::with_budget_bits(weight_bits);
    let mut lrng = Rng::seed_from_u64(3);
    lut.populate_from(&doc.ddos, &mut lrng);
    let lut_acc = lut.accuracy(&trace.keys, &trace.labels);
    println!(
        "    equal-SRAM baseline: BNN {:.2}% vs LUT {:.2}% ({} bits, {} LUT entries)",
        acc * 100.0,
        lut_acc * 100.0,
        weight_bits,
        lut.n_entries()
    );

    println!("\nE2E PASSED — all three implementations agree bit-for-bit.");
    Ok(())
}
