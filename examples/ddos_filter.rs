//! Use case 1 (paper §1): DDoS white/blacklisting in the switch —
//! the accuracy-vs-SRAM comparison against exact-match lookup tables
//! (experiment E8).
//!
//! Uses the JAX-trained model from `make artifacts`. Sweeps the LUT's
//! SRAM budget to show the crossover the paper's motivation predicts:
//! point entries cannot cover subnet-structured attackers, while the
//! BNN generalizes from ~4 kbit of weights.
//!
//! ```bash
//! make artifacts && cargo run --release --example ddos_filter
//! ```

use n2net::apps::DdosFilter;
use n2net::baseline::LutClassifier;
use n2net::bnn;
use n2net::net::{TraceGenerator, TraceKind};
use n2net::rmt::ChipConfig;
use n2net::runtime::Oracle;
use n2net::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = Oracle::default_dir();
    let (model, doc) = bnn::load_weights(dir.join("weights.json"))?;
    println!(
        "trained model: {}b -> {:?}, python test accuracy {:.2}%",
        model.spec.in_bits,
        model.spec.layer_sizes,
        doc.metrics.test_accuracy_packed * 100.0
    );
    println!(
        "blacklist structure: {} attacker subnets (/12../20)\n",
        doc.ddos.subnets.len()
    );

    // The in-switch BNN filter.
    let mut filter = DdosFilter::new(&model, ChipConfig::rmt(), doc.ddos.clone())?;
    let n_packets = 4000;
    let mut gen = TraceGenerator::new(1234);
    let trace = gen.generate(&TraceKind::Ddos { ddos: doc.ddos.clone() }, n_packets);
    let bnn_eval = filter.evaluate(&trace)?;
    println!(
        "BNN on switch: accuracy {:.2}%  FPR {:.2}%  FNR {:.2}%  (weights: {} bits)",
        bnn_eval.accuracy * 100.0,
        bnn_eval.false_positive_rate * 100.0,
        bnn_eval.false_negative_rate * 100.0,
        filter.compiled.resources.weight_bits,
    );
    let t = filter.compiled.chip.timing(&filter.compiled.program);
    println!(
        "modeled line rate: {:.0} M packets/s classified in-network\n",
        t.pps / 1e6
    );

    // LUT baseline across SRAM budgets (E8's crossover series).
    println!("exact-match LUT baseline vs SRAM budget:");
    println!(
        "{:>14} {:>10} {:>10} {:>8} {:>8}",
        "SRAM (bits)", "entries", "accuracy", "FPR", "FNR"
    );
    let mut rng = Rng::seed_from_u64(55);
    for budget_bits in [
        4_096usize, // what the BNN uses
        65_536,
        1_048_576,  // 1 Mbit
        11_562_500, // one element's full SRAM
    ] {
        let mut lut = LutClassifier::with_budget_bits(budget_bits);
        lut.populate_from(&doc.ddos, &mut rng);
        let (mut fp, mut fng, mut pos, mut neg, mut correct) = (0, 0, 0usize, 0usize, 0usize);
        for (&k, &l) in trace.keys.iter().zip(&trace.labels) {
            let p = lut.classify(k);
            if p == l {
                correct += 1;
            }
            if l == 1 {
                pos += 1;
                if p == 0 {
                    fng += 1;
                }
            } else {
                neg += 1;
                if p == 1 {
                    fp += 1;
                }
            }
        }
        println!(
            "{:>14} {:>10} {:>9.2}% {:>7.2}% {:>7.2}%",
            budget_bits,
            lut.n_entries(),
            correct as f64 / n_packets as f64 * 100.0,
            fp as f64 / neg.max(1) as f64 * 100.0,
            fng as f64 / pos.max(1) as f64 * 100.0,
        );
    }

    println!(
        "\nE8 takeaway: the attacker population (~{} /12../20 subnets ≈ millions of\n\
         addresses) cannot be enumerated in point entries — even 1 Mbit of SRAM\n\
         leaves the LUT near chance on unseen attackers, while the {}-bit BNN\n\
         generalizes across each subnet at line rate.",
        doc.ddos.subnets.len(),
        filter.compiled.resources.weight_bits,
    );
    Ok(())
}
