//! Use case 2 (paper §1): the switch writes the BNN output into the
//! header as a *hint* the downstream servers use for load balancing /
//! data placement (cf. Sharma et al., NSDI'17 — the paper's ref [15]).
//!
//! The BNN's output bits select a server queue: packets with similar
//! header features land together (locality), flows stay affine, and the
//! population spreads. Compared against plain flow hashing.
//!
//! ```bash
//! cargo run --release --example lb_hints
//! ```

use n2net::apps::lb_hints::{hash_route_report, HintRouter};
use n2net::bnn::BnnModel;
use n2net::net::{TraceGenerator, TraceKind};
use n2net::rmt::ChipConfig;

fn main() -> anyhow::Result<()> {
    // A compact BNN producing a 16-bit feature vector; the low 2 bits
    // select one of 4 server queues.
    let model = BnnModel::random(32, &[16], 77);
    let hint_bits = 2;
    let mut router = HintRouter::new(&model, ChipConfig::rmt(), hint_bits)?;
    println!(
        "hint router: {}b IP -> {} neurons, {} hint bits -> {} servers",
        model.spec.in_bits,
        model.spec.layer_sizes[0],
        hint_bits,
        1 << hint_bits
    );
    print!("{}", router.compiled.resource_report());
    println!();

    let mut gen = TraceGenerator::new(31);
    for (name, kind, n) in [
        ("uniform IPs", TraceKind::UniformIps, 8000),
        ("zipf flows (100)", TraceKind::ZipfFlows { n_flows: 100 }, 8000),
    ] {
        let trace = gen.generate(&kind, n);
        let bnn = router.evaluate(&trace)?;
        let hash = hash_route_report(&trace, hint_bits);
        println!("--- workload: {name} ({n} packets) ---");
        println!("  {}", bnn.render("BNN hints "));
        println!("  {}", hash.render("flow hash "));
    }

    println!(
        "\nthe BNN hint is computed at line rate inside the switch and carried\n\
         in the header — the server reads a single field instead of re-running\n\
         its own classifier (the paper's \"hints to a more complex processor\")."
    );
    Ok(())
}
