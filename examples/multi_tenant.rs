//! Multi-model extension: one pipeline program, several BNNs — a packet
//! header field selects the weights per packet (tenant / policy id) —
//! now one builder call away: `Deployment::builder().keyed(id_offset)`.
//!
//! The paper pre-configures one model's weights into the element SRAMs;
//! the match stage makes that SRAM *addressable*: keying the XNOR
//! elements' tables on a model-id container serves many models from the
//! same 30-element program at the same line rate, paying only table
//! entries (SRAM), not pipeline stages. And because the deployment owns
//! publication, a tenant's retrained model hot-swaps in at runtime
//! without touching the other tenants.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::deploy::{Deployment, FieldExtractor};
use n2net::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // Three tenants, one architecture. (32+16 rather than the paper's
    // full-capacity 64+32: reserving a PHV container for the tenant id
    // costs one container, and 64 parallel neurons use all 128 — with
    // the id reserved, the 64+32 shape still compiles but spills to two
    // passes. A real constraint, worth knowing.)
    let tenants: Vec<(&str, u32, BnnModel)> = vec![
        ("tenant-a", 1001, BnnModel::random(32, &[32, 16], 11)),
        ("tenant-b", 2002, BnnModel::random(32, &[32, 16], 22)),
        ("tenant-c", 3003, BnnModel::random(32, &[32, 16], 33)),
    ];

    // Packet: [tenant id u32 LE][activation words LE].
    let mut builder = Deployment::builder()
        .extractor(FieldExtractor::PayloadAt { offset: 4 })
        .keyed(0);
    for (name, id, model) in &tenants {
        builder = builder.model_with_id(*name, *id, model.clone());
    }
    let deployment = builder.build()?;

    println!("one program, {} tenants:", tenants.len());
    let compiled = deployment.compiled("tenant-a")?;
    print!("{}", compiled.resource_report());
    println!(
        "(same {} elements as a single-model deployment — extra models cost \
         SRAM entries, not stages)\n",
        compiled.program.n_elements()
    );

    let mut session = deployment.keyed_session()?;
    let frame = |id: u32, x: &PackedBits| -> Vec<u8> {
        let mut pkt = id.to_le_bytes().to_vec();
        for w in x.words() {
            pkt.extend_from_slice(&w.to_le_bytes());
        }
        pkt
    };

    // Same activation vector, three tenants → three different answers,
    // each bit-exact with that tenant's reference model.
    let mut rng = Rng::seed_from_u64(9);
    let x = PackedBits::random(32, &mut rng);
    println!("activations: {x:?}");
    let mask = n2net::backend::out_mask(16);
    for (name, id, model) in &tenants {
        let pkt = frame(*id, &x);
        let refs: Vec<&[u8]> = vec![&pkt];
        let mut out = Vec::new();
        session.classify_batch(&refs, &mut out)?;
        let expect = bnn::forward(model, &x).words()[0] & mask;
        assert_eq!(out[0], expect);
        println!("{name} (id {id}): output {:08x} (≡ tenant's reference model ✓)", out[0]);
    }

    // Hot-swap tenant-b's retrained model; the other tenants' answers
    // must not move.
    let retrained = BnnModel::random(32, &[32, 16], 2222);
    let v = deployment.swap_model("tenant-b", retrained.clone())?;
    println!("\nhot-swapped tenant-b's retrained model in as program v{v}");
    for (name, id, model) in &tenants {
        let expect_model = if *name == "tenant-b" { &retrained } else { model };
        let pkt = frame(*id, &x);
        let refs: Vec<&[u8]> = vec![&pkt];
        let mut out = Vec::new();
        session.classify_batch(&refs, &mut out)?;
        let expect = bnn::forward(expect_model, &x).words()[0] & mask;
        assert_eq!(out[0], expect);
        println!(
            "{name} (id {id}): output {:08x} ({})",
            out[0],
            if *name == "tenant-b" { "retrained model ✓" } else { "unchanged ✓" }
        );
    }

    println!("\nall tenants served by the same pipeline at line rate.");

    // ---- Scenario traffic through the sharded tier -------------------
    // The same three tenants on the wire encoding: Ethernet frames with
    // the tenant id at MODEL_ID_OFFSET (what `n2net serve --models`
    // uses), served by the flow-affinity shard tier under a
    // multi-tenant-mix workload (10% unknown ids → table miss → default
    // model). Every shard serves every tenant — the keyed tables ride
    // in the program, not in the shard.
    let mut wire_builder = Deployment::builder()
        .extractor(FieldExtractor::SrcIp)
        .keyed(n2net::net::MODEL_ID_OFFSET);
    for (name, id, model) in &tenants {
        wire_builder = wire_builder.model_with_id(*name, *id, model.clone());
    }
    let wire = wire_builder.build()?;
    let ids: Vec<u32> = tenants.iter().map(|(_, id, _)| *id).collect();
    let mix = n2net::net::Scenario::parse("multi-tenant-mix")?
        .with_model_ids(ids)
        .generate(7, 8000);
    let engine_out = wire.serve_trace_keyed(&mix.packets)?.outputs;
    let sharded = wire.sharded_engine_keyed(4)?.process_trace(&mix.packets)?;
    assert_eq!(sharded.outputs, engine_out);
    println!(
        "\nmulti-tenant-mix through {} shards: {:.2} M pkt/s aggregate, \
         imbalance {:.2}, versions v{}..v{} (≡ keyed engine ✓)",
        sharded.per_shard.len(),
        sharded.sim_pps / 1e6,
        sharded.imbalance(),
        sharded.version_min,
        sharded.version_max,
    );
    Ok(())
}
