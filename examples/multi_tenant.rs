//! Multi-model extension: one pipeline program, several BNNs — a packet
//! header field selects the weights per packet (tenant / policy id).
//!
//! The paper pre-configures one model's weights into the element SRAMs;
//! the match stage makes that SRAM *addressable*: keying the XNOR
//! elements' tables on a model-id container serves many models from the
//! same 30-element program at the same line rate, paying only table
//! entries (SRAM), not pipeline stages.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use n2net::bnn::{self, BnnModel, PackedBits};
use n2net::compiler::{Compiler, CompilerOptions, InputEncoding, MultiModelOptions};
use n2net::rmt::{ChipConfig, Pipeline};
use n2net::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // Three tenants, one architecture. (32+16 rather than the paper's
    // full-capacity 64+32: reserving a PHV container for the tenant id
    // costs one container, and 64 parallel neurons use all 128 — with
    // the id reserved, the 64+32 shape still compiles but spills to two
    // passes. A real constraint, worth knowing.)
    let tenants: Vec<(u32, BnnModel)> = vec![
        (1001, BnnModel::random(32, &[32, 16], 11)),
        (2002, BnnModel::random(32, &[32, 16], 22)),
        (3003, BnnModel::random(32, &[32, 16], 33)),
    ];

    let opts = CompilerOptions {
        // Packet: [tenant id u32 LE][activation words LE].
        input: InputEncoding::PayloadLe { offset: 4 },
        ..Default::default()
    };
    let compiled = Compiler::new(ChipConfig::rmt(), opts)
        .compile_multi(&tenants, MultiModelOptions { id_offset: 0 })?;

    println!("one program, {} tenants:", tenants.len());
    print!("{}", compiled.resource_report());
    println!(
        "(same {} elements as a single-model deployment — extra models cost \
         SRAM entries, not stages)\n",
        compiled.program.n_elements()
    );

    let mut pipe = Pipeline::new(
        ChipConfig::rmt(),
        compiled.program.clone(),
        compiled.parser.clone(),
        false,
    )?;

    // Same activation vector, three tenants → three different answers,
    // each bit-exact with that tenant's reference model.
    let mut rng = Rng::seed_from_u64(9);
    let x = PackedBits::random(32, &mut rng);
    println!("activations: {x:?}");
    for (id, model) in &tenants {
        let mut pkt = id.to_le_bytes().to_vec();
        for w in x.words() {
            pkt.extend_from_slice(&w.to_le_bytes());
        }
        let out = compiled.read_output(&pipe.process_packet(&pkt)?);
        let expect = bnn::forward(model, &x);
        assert_eq!(out, expect);
        println!(
            "tenant {id}: output {:08x} (≡ tenant's reference model ✓)",
            out.words()[0]
        );
    }
    println!("\nall tenants served by the same pipeline at line rate.");
    Ok(())
}
