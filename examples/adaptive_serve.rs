//! Closed-loop adaptive serving: the control plane reacting to an
//! attack, end to end (DESIGN.md §13).
//!
//!   scenario sequence  uniform → ddos-burst → uniform
//!     → sharded serving tier classifies every frame (2 shards)
//!     → per-window signals pulled off the tier (class mix, pressure,
//!       shard balance, version skew — zero per-packet cost)
//!     → detectors see the attacker-class share ramping
//!     → policy fires ONCE (hysteresis), hot-swapping to the "attack"
//!       model through the deployment's publication slot
//!     → attack subsides, the condition clears, and the (re-armed)
//!       loop stays quiet — no flapping, no further swaps
//!
//! Runs hermetically: the served model is a hand-built subnet
//! classifier, so no trained artifacts are needed.
//!
//! ```bash
//! cargo run --release --example adaptive_serve
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::ensure;
use n2net::controlplane::{
    prefix_classifier, sim_ddos, spawn_live, Controller, LiveConfig, ManualClock,
    ModelBank, Policy, Sim, SimConfig,
};
use n2net::deploy::{Deployment, FieldExtractor, SwapHandle};
use n2net::net::{Scenario, ScenarioSequence};

fn main() -> anyhow::Result<()> {
    println!("=== N2Net closed-loop adaptive serving ===\n");

    // ---- 1. The live deployment -------------------------------------
    // One neuron whose weight row is the attack subnet's pattern: every
    // member of 192.168.0.0/16 clears the majority threshold, uniform
    // addresses only ~57% of the time — a deterministic detector-grade
    // classifier with no training loop.
    let day = prefix_classifier(0xC0A8_0000);
    let attack = prefix_classifier(0xC0A8_FFFF);
    let deployment = Arc::new(
        Deployment::builder()
            .extractor(FieldExtractor::SrcIp)
            .model("live", day.clone())
            .build()?,
    );
    println!(
        "[1] deployed \"live\" ({}b -> {:?}) v{}",
        day.spec.in_bits,
        day.spec.layer_sizes,
        deployment.version("live")?
    );

    // ---- 2. The control plane ---------------------------------------
    let bank = ModelBank::new("day", day.clone()).with_model("attack", attack);
    let policy = Policy::parse(
        "on ddos-ramp do swap attack cooldown=4\n\
         on drift     do alert cooldown=8\n\
         on overload  do alert cooldown=8\n",
    )?;
    println!("[2] policy:\n{}", policy.render());

    // ---- 3. The condition change ------------------------------------
    let seq = ScenarioSequence::new(vec![
        (Scenario::Uniform, 2048),
        (Scenario::DdosBurst { ddos: sim_ddos(), peak_fraction: 0.9 }, 4096),
        (Scenario::Uniform, 2048),
    ]);
    println!("[3] sequence: {}\n", seq.name());

    // ---- 4. Run the loop --------------------------------------------
    let cfg = SimConfig { n_shards: 2, window_packets: 512, seed: 11 };
    let mut sim = Sim::new(&deployment, "live", bank, policy, cfg)?;
    let report = sim.run_sequence(&seq)?;
    print!("{}", report.render());

    // ---- 5. What the loop guarantees --------------------------------
    ensure!(report.swaps.len() == 1, "exactly one swap per ramp episode");
    ensure!(report.false_swaps == 0, "no swaps outside the attack");
    let reaction = report
        .reaction_windows
        .expect("the ramp must be caught");
    ensure!(reaction <= 8, "bounded reaction, got {reaction}");
    println!(
        "\nreacted in {reaction} windows ({} frames); final version v{}",
        reaction as usize * cfg.window_packets,
        deployment.version("live")?
    );

    // ---- 6. The same loop, LIVE (DESIGN.md §14) ---------------------
    // The production shape: a background controller thread over a
    // streaming tier, here with a TIER action — the ramp reshards the
    // tier from 2 to 4 shards, and the LiveStream drains-and-rebuilds
    // mid-stream with outputs intact. The lockstep clock keeps the
    // demo deterministic: each step() returns after the tick finishes.
    println!("\n--- live controller thread ---");
    let day2 = prefix_classifier(0xC0A8_0000);
    let dep2 = Arc::new(
        Deployment::builder()
            .extractor(FieldExtractor::SrcIp)
            .model("live", day2.clone())
            .build()?,
    );
    let engine = dep2.live_sharded_engine("live", 2)?;
    let controller = Controller::new(
        SwapHandle::new(&dep2, "live")?,
        ModelBank::new("day", day2),
        Policy::parse("on ddos-ramp do reshard 4 cooldown=4")?,
    )?
    .with_tier(Arc::clone(&engine))?;
    let (clock, driver) = ManualClock::pair();
    let live = spawn_live(
        Arc::clone(&engine),
        controller,
        Box::new(clock),
        LiveConfig::default(),
    );
    let st = seq.generate(23);
    let mut stream = engine.live_stream()?;
    for chunk in st.trace.packets.chunks(cfg.window_packets) {
        for pkt in chunk {
            stream.push(pkt.clone())?;
        }
        ensure!(
            stream.quiesce(Duration::from_secs(30)),
            "window failed to quiesce"
        );
        ensure!(driver.step(), "controller thread alive");
    }
    let live_report = stream.finish()?;
    let controller = live.stop();
    for e in controller.events() {
        println!("  {}", e.render());
    }
    ensure!(controller.reconfigs() == 1, "the ramp reshards the tier once");
    ensure!(live_report.reconfigs() == 1, "the stream drained and rebuilt");
    ensure!(engine.n_shards() == 4, "tier now serves with 4 shards");
    println!(
        "live loop: {} frames over {} epoch(s); tier resharded 2 -> {} shards \
         mid-stream, zero frames lost ({} delivered)",
        live_report.n_packets,
        live_report.epochs.len(),
        engine.n_shards(),
        live_report.delivered(),
    );
    println!("adaptive serving demo PASSED");
    Ok(())
}
